"""In-process gateway data plane moving REAL bytes (paper §3.3/§6).

The fluid simulator (flowsim) models timing; this module implements the
actual mechanics on real data — chunking, bounded relay queues (hop-by-hop
flow control), parallel workers per hop, dynamic chunk dispatch, per-chunk
checksum verification at the destination — and is what checkpoint
replication (repro.ckpt.replicate) runs on. Object stores are pluggable
(in-memory dict or a directory), mirroring S3/Blob/GCS semantics: immutable
puts, no rename.

Fault tolerance (ISSUE 2): every chunk carries a source-side checksum, the
destination verifies and commits chunks independently, and failed chunks —
a killed hop worker, a corrupted payload, a chunk stranded in a dead
path's queues — are re-dispatched to surviving workers. Verified bytes are
never re-sent (chunk-level checksummed resume), duplicate deliveries are
discarded, and a ``FaultInjector`` scripts the same failure scenarios the
fluid simulator runs (events.VMFailure / LinkDegrade analogues) against
the real-bytes path.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from pathlib import Path

from repro.core.plan import TransferPlan
from .chunk import Chunk, checksum, chunk_manifest, chunk_object


class ObjectStore:
    """Interface of an object store (S3/Blob/GCS-like semantics)."""

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def keys(self) -> list[str]:
        raise NotImplementedError

    def size(self, key: str) -> int:
        raise NotImplementedError


class BlobStore(ObjectStore):
    """In-memory object store."""

    def __init__(self):
        self._data: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._data[key] = bytes(data)

    def get(self, key: str) -> bytes:
        with self._lock:
            return self._data[key]

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        with self._lock:
            return self._data[key][offset : offset + length]

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._data)

    def size(self, key: str) -> int:
        with self._lock:
            return len(self._data[key])


class DirStore(ObjectStore):
    """Directory-backed store (used by the checkpoint replicator).

    The directory is authoritative: every read is served from disk and no
    in-memory copy of object payloads is kept, so replicating a large
    checkpoint costs one resident copy, not two."""

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, key: str) -> Path:
        return self.root / key.replace("/", "__")

    def put(self, key: str, data: bytes) -> None:
        p = self._path(key)
        tmp = p.with_name(p.name + ".tmp")
        tmp.write_bytes(data)
        tmp.rename(p)  # atomic within the fs

    def get(self, key: str) -> bytes:
        return self._path(key).read_bytes()

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        with open(self._path(key), "rb") as f:
            f.seek(offset)
            return f.read(length)

    def exists(self, key: str) -> bool:
        return self._path(key).exists()

    def keys(self) -> list[str]:
        return sorted(p.name.replace("__", "/") for p in self.root.iterdir()
                      if not p.name.endswith(".tmp"))

    def size(self, key: str) -> int:
        return self._path(key).stat().st_size


class FaultInjector:
    """Scripted faults for the real-bytes path.

    * ``kill_worker_after={(path_id, hop): n}`` — one worker on that hop
      dies when it picks up its (n+1)-th chunk; the chunk it carried is
      lost and re-dispatched (the gateway-kill scenario of
      ``events.VMFailure``). With ``workers_per_hop >= 2`` the hop
      survives on its remaining workers.
    * ``corrupt_chunks={chunk_id, ...}`` — the payload is corrupted once in
      flight; the destination's per-chunk checksum catches it and the
      chunk retries (a flaky link, ``events.LinkDegrade``'s ugly cousin).

    ``faults_injected`` counts every fault actually fired.
    """

    def __init__(self, *, kill_worker_after=None, corrupt_chunks=None):
        self.kill_worker_after: dict[tuple[int, int], int] = dict(
            kill_worker_after or {}
        )
        self.corrupt_chunks: set[str] = set(corrupt_chunks or ())
        self.faults_injected = 0
        self._lock = threading.Lock()
        self._pickups: dict[tuple[int, int], int] = {}
        self._killed: set[tuple[int, int]] = set()

    def on_pickup(self, path_id: int, hop: int, ch: Chunk, data: bytes,
                  attempt: int) -> tuple[str, bytes | None]:
        """Called by a hop worker for every chunk it picks up.

        Returns ("ok", data), ("kill", None) — the worker must requeue the
        chunk and die — or ("corrupt", mangled_payload)."""
        with self._lock:
            key = (path_id, hop)
            if key in self.kill_worker_after and key not in self._killed:
                n = self._pickups.get(key, 0)
                self._pickups[key] = n + 1
                if n >= self.kill_worker_after[key]:
                    self._killed.add(key)
                    self.faults_injected += 1
                    return "kill", None
            if data is not None and ch.id in self.corrupt_chunks:
                self.corrupt_chunks.discard(ch.id)
                self.faults_injected += 1
                return "corrupt", bytes([data[0] ^ 0xFF]) + data[1:]
        return "ok", data


@dataclasses.dataclass
class GatewayReport:
    objects: int
    chunks: int
    bytes_moved: int
    checksum_failures: int  # objects whose final assembly failed to verify
    per_path_chunks: dict
    retried_chunks: int = 0  # chunk re-dispatches (kills, corruption, stalls)
    duplicate_chunks: int = 0  # deliveries discarded as already-verified
    faults_injected: int = 0
    objects_skipped: int = 0  # already present + verified at the destination
    chunks_missing: int = 0  # gave up after max_attempts (0 == zero loss)


def _same_object(src_store: ObjectStore, dst_store: ObjectStore, key: str,
                 window: int) -> bool:
    """Streamed equality check for the resume pre-pass: size short-circuit,
    then windowed get_range comparison — no whole-object materialization,
    early exit on the first differing window."""
    size = src_store.size(key)
    if dst_store.size(key) != size:
        return False
    off = 0
    while off < size:
        n = min(window, size - off)
        if src_store.get_range(key, off, n) != dst_store.get_range(key, off, n):
            return False
        off += n
    return True


def transfer_objects(
    plan: TransferPlan,
    src_store: ObjectStore,
    dst_store: ObjectStore,
    object_keys: list[str],
    *,
    chunk_bytes: int = 4 << 20,
    workers_per_hop: int = 4,
    relay_buffer_chunks: int = 32,
    verify: bool = True,
    fault_injector: FaultInjector | None = None,
    max_attempts: int = 5,
    stall_timeout_s: float = 1.0,
    resume: bool = True,
) -> GatewayReport:
    """Move objects src->dst along the plan's decomposed paths.

    Every path becomes a chain of bounded queues with ``workers_per_hop``
    threads per hop — a faithful miniature of the gateway chain: bounded
    queues ARE the hop-by-hop flow control; idle workers pulling from the
    shared source queue ARE dynamic dispatch. The destination verifies and
    commits chunks independently; anything lost in flight is re-dispatched
    to a surviving path (``max_attempts`` per chunk), so a mid-transfer
    gateway kill completes with zero data loss and no verified byte is
    ever sent twice. ``resume=True`` additionally skips whole objects the
    destination already holds with a matching checksum.
    """
    paths = plan.paths()
    if not paths:
        raise ValueError("plan has no flow")

    skipped = 0
    keys_to_move = []
    for key in object_keys:
        if (
            resume and verify and dst_store.exists(key)
            and _same_object(src_store, dst_store, key, chunk_bytes)
        ):
            skipped += 1
            continue
        keys_to_move.append(key)

    all_chunks, chunk_sums, object_sums = chunk_manifest(
        src_store, keys_to_move, chunk_bytes, with_sums=verify
    )
    # zero-byte objects produce no chunks: commit them directly so they are
    # not silently dropped by the chunk-delivery loop
    chunked = {ch.object_key for ch in all_chunks}
    for key in keys_to_move:
        if key not in chunked:
            dst_store.put(key, b"")
    keys_to_move = [k for k in keys_to_move if k in chunked]

    # weighted round-robin pre-binning of chunks to paths
    weights = [f for _, f in paths]
    total_w = sum(weights)
    bins: list[list[Chunk]] = [[] for _ in paths]
    cum = [w / total_w for w in weights]
    acc = [0.0] * len(paths)
    for ch in all_chunks:
        i = max(range(len(paths)), key=lambda j: cum[j] - acc[j])
        bins[i].append(ch)
        acc[i] += 1.0 / max(len(all_chunks), 1)
    per_path_count = {i: len(b) for i, b in enumerate(bins)}

    done_event = threading.Event()
    done_q: "queue.Queue" = queue.Queue()
    retry_q: "queue.Queue" = queue.Queue()
    lock = threading.Lock()
    bytes_moved = [0]
    retried = [0]
    live = {(pid, h): workers_per_hop
            for pid, (path, _) in enumerate(paths)
            for h in range(len(path) - 1)}

    def _put(q: queue.Queue, item) -> None:
        while not done_event.is_set():
            try:
                q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    first_qs: list[queue.Queue] = []
    threads: list[threading.Thread] = []
    for pid, (path, _flow) in enumerate(paths):
        hops = len(path) - 1
        qs: list[queue.Queue] = [queue.Queue()]
        for _ in range(hops - 1):
            qs.append(queue.Queue(maxsize=relay_buffer_chunks))  # flow ctrl
        qs.append(done_q)
        first_qs.append(qs[0])
        for ch in bins[pid]:
            qs[0].put((ch, 0))

        def hop_worker(pid: int, h: int, q_in: queue.Queue,
                       q_out: queue.Queue, first: bool):
            while not done_event.is_set():
                try:
                    item = q_in.get(timeout=0.05)
                except queue.Empty:
                    continue
                if first:
                    ch, attempt = item
                    data = src_store.get_range(ch.object_key, ch.offset,
                                               ch.length)
                else:
                    ch, data, attempt = item
                if fault_injector is not None:
                    action, data = fault_injector.on_pickup(
                        pid, h, ch, data, attempt
                    )
                    if action == "kill":
                        with lock:
                            live[(pid, h)] -= 1
                        retry_q.put((ch, attempt + 1))
                        return  # the worker thread dies with its chunk
                with lock:
                    bytes_moved[0] += len(data)
                _put(q_out, (ch, data, attempt))

        for h in range(hops):
            for _ in range(workers_per_hop):
                t = threading.Thread(
                    target=hop_worker, args=(pid, h, qs[h], qs[h + 1], h == 0),
                    daemon=True,
                )
                threads.append(t)
                t.start()

    # retry feeder: re-dispatch lost chunks onto any path whose every hop
    # still has a live worker (dynamic dispatch across surviving gateways)
    attempts: dict[str, int] = {}
    dead: set[str] = set()
    verified: set[str] = set()
    rr = [0]

    def alive_paths() -> list[int]:
        with lock:
            return [
                pid for pid, (path, _) in enumerate(paths)
                if all(live[(pid, h)] > 0 for h in range(len(path) - 1))
            ]

    def feeder():
        while not done_event.is_set():
            try:
                ch, attempt = retry_q.get(timeout=0.05)
            except queue.Empty:
                continue
            if ch.id in verified:
                continue  # a duplicate copy already landed: nothing to do
            if attempt > max_attempts:
                dead.add(ch.id)
                continue
            targets = alive_paths()
            if not targets:
                dead.add(ch.id)
                continue
            with lock:
                retried[0] += 1
            pid = targets[rr[0] % len(targets)]
            rr[0] += 1
            attempts[ch.id] = max(attempts.get(ch.id, 0), attempt)
            first_qs[pid].put((ch, attempt))

    feeder_t = threading.Thread(target=feeder, daemon=True)
    feeder_t.start()

    # destination: verify + commit chunks independently, reassemble objects
    buffers: dict[str, dict[int, bytes]] = {k: {} for k in keys_to_move}
    expect = {
        k: len(chunk_object(k, src_store.size(k), chunk_bytes))
        for k in keys_to_move
    }
    duplicates = 0
    failures = 0
    stall_rounds = 0
    # adaptive stall detection: a pipeline is only declared stuck once the
    # quiet period exceeds both the configured window and twice the worst
    # inter-delivery gap seen so far, so a slow-but-healthy transfer (cold
    # disk, big chunks) is not flooded with wholesale re-dispatches
    max_gap = stall_timeout_s
    last_delivery = time.monotonic()
    while len(verified) + len(dead - verified) < len(all_chunks):
        try:
            ch, data, attempt = done_q.get(timeout=stall_timeout_s)
        except queue.Empty:
            quiet = time.monotonic() - last_delivery
            if quiet < max(stall_timeout_s, 2.0 * max_gap):
                continue  # plausibly just slow: keep waiting
            # Stuck: every in-flight copy died or sits behind a dead hop.
            # Re-dispatch the missing chunks — the checksummed-resume path:
            # verified chunks are never re-sent. Stall re-sends are bounded
            # by their own round counter (reset on progress), NOT by
            # per-chunk attempts, so timeouts alone never fail a transfer.
            stall_rounds += 1
            missing = [c for c in all_chunks
                       if c.id not in verified and c.id not in dead]
            if not missing or stall_rounds > max_attempts:
                break
            for c in missing:
                retry_q.put((c, attempts.get(c.id, 0)))
            last_delivery = time.monotonic()  # re-arm for the next round
            continue
        now_t = time.monotonic()
        max_gap = max(max_gap, now_t - last_delivery)
        last_delivery = now_t
        stall_rounds = 0
        if ch.id in verified:
            duplicates += 1
            continue
        if verify and checksum(data) != chunk_sums[ch.id]:
            retry_q.put((ch, attempt + 1))
            continue
        verified.add(ch.id)
        dead.discard(ch.id)
        buffers[ch.object_key][ch.index] = data
        if len(buffers[ch.object_key]) == expect[ch.object_key]:
            parts = buffers[ch.object_key]
            blob = b"".join(parts[i] for i in range(len(parts)))
            if verify and checksum(blob) != object_sums[ch.object_key]:
                failures += 1
            dst_store.put(ch.object_key, blob)

    done_event.set()
    feeder_t.join(timeout=2.0)
    for t in threads:
        t.join(timeout=2.0)

    missing = len(all_chunks) - len(verified)
    return GatewayReport(
        objects=len(object_keys),
        chunks=len(all_chunks),
        bytes_moved=bytes_moved[0],
        checksum_failures=failures,
        per_path_chunks=per_path_count,
        retried_chunks=retried[0],
        duplicate_chunks=duplicates,
        faults_injected=0 if fault_injector is None
        else fault_injector.faults_injected,
        objects_skipped=skipped,
        chunks_missing=missing,
    )
