"""One report protocol for every outcome dataclass (ISSUE 7 API redesign).

``JobReport`` / ``ServiceReport`` (executor), ``GatewayReport`` /
``MulticastGatewayReport`` (real-bytes data plane),
``CalibratedServiceReport`` (calibration loop) and ``FleetReport``
(fleet control plane) each grew their own field spellings — per-edge
telemetry was ``per_edge_bytes``/``per_edge_seconds`` on gateways but
``per_edge_gb`` on sim results, multicast outcomes were ``per_dest`` here
and ``per_dst_delivered`` there. Consumers (``benchmarks/compare.py``,
``fleet_bench``) now read ONE shape:

  * ``to_dict()`` — a plain-JSON dict with a ``kind`` tag and canonical
    key names: ``per_edge`` is ``{"a->b": {"gb", "seconds", "gbps"}}``,
    per-destination breakdowns are ``per_dst``;
  * ``summary()`` — a one-line human rendering of the headline fields
    (each class declares them in ``_summary_keys``).

The mixin is field-free so dataclasses can inherit it without changing
their layout; legacy attributes stay (the protocol normalizes names at
the boundary instead of breaking every caller at once).
"""

from __future__ import annotations


def edge_key(edge) -> str:
    """Canonical spelling of a region-pair edge: ``"a->b"``.

    Accepts (index, index) or (key, key) pairs — whatever the producer
    tracked; the dict form is for humans and JSON, not for joins."""
    a, b = edge
    return f"{a}->{b}"


def per_edge_dict(bytes_map, seconds_map) -> dict:
    """Normalize the two parallel per-edge maps into the canonical shape."""
    out: dict = {}
    for e, nbytes in (bytes_map or {}).items():
        secs = float((seconds_map or {}).get(e, 0.0))
        gb = float(nbytes) / 1e9
        out[edge_key(e)] = {
            "gb": gb,
            "seconds": secs,
            "gbps": (gb * 8.0 / secs) if secs > 1e-9 else 0.0,
        }
    return out


def _fmt(v) -> str:
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, float):
        return f"{v:.3g}"
    return str(v)


class Report:
    """Field-free mixin: the ``to_dict()`` / ``summary()`` protocol.

    Subclasses set ``kind`` (the dict's type tag), implement
    ``_payload()`` (their fields under canonical names), and list their
    headline keys in ``_summary_keys``. A class that names registry
    planes in ``_metrics_prefixes`` (``("gateway.",)`` etc.) gets a
    ``metrics`` section in its dict: the matching non-zero instruments
    from ``repro.obs.metrics`` at render time."""

    kind: str = "report"
    _summary_keys: tuple = ()
    _metrics_prefixes: tuple = ()

    def _payload(self) -> dict:
        raise NotImplementedError

    def to_dict(self) -> dict:
        d = {"kind": self.kind, **self._payload()}
        if self._metrics_prefixes:
            # lazy import: reports is imported by every plane the
            # registry instruments, so a top-level import would cycle
            from repro.obs.metrics import get_registry

            metrics = get_registry().snapshot(self._metrics_prefixes)
            if metrics:
                d["metrics"] = metrics
        return d

    def summary(self) -> str:
        d = self.to_dict()
        parts = " ".join(
            f"{k}={_fmt(d[k])}" for k in self._summary_keys if k in d
        )
        return f"[{self.kind}] {parts}".rstrip()
