"""transfer.sim — the one sanctioned multi-job simulation entry point.

``simulate`` fronts the three engines behind one signature (the legacy
kwargs of the historical per-engine functions, plus ``engine``):

  * ``"ref"`` — object-per-connection oracle (``flowsim_ref``), the
    semantics ground truth; dict/list bookkeeping, slowest;
  * ``"soa"`` — vectorized numpy event loop (``flowsim``), the default;
  * ``"jax"`` — fixed-shape accelerator-resident loop (``flowsim_jax``):
    the event loop runs under ``lax.while_loop`` with a masked
    water-filling solver (Pallas kernel on TPU backends), chunk-for-chunk
    identical to the other two.

The per-engine entry points (``flowsim.simulate_multi``,
``flowsim_ref.simulate_multi_reference``) are deprecated shims kept for
backward compatibility; ``analysis.rules`` SKY010 bans new first-party
calls to them. The registry is a plain if/elif chain on purpose — a
module-level dict of engine callables would be mutable import-time state
(SKY007) and would force eager imports of every engine (the jax engine
pulls in the accelerator stack, which the numpy paths must not pay for).
"""

from __future__ import annotations

from .simconfig import ENGINE_NAMES, SimConfig
from .simconfig import resolve as resolve_sim_config

__all__ = ["simulate"]


def simulate(
    jobs,
    faults=(),
    *,
    config: SimConfig | None = None,
    link_capacity_scale: float | None = 2.0,
    straggler_prob: float = 0.05,
    straggler_speed: tuple[float, float] = (0.15, 0.5),
    relay_buffer_chunks: int = 64,
    seed: int = 0,
    horizon_s: float | None = None,
    exec_top=None,
    drain: bool = False,
    engine: str = "soa",
):
    """Run a multi-job transfer scenario on the selected engine.

    Accepts either a :class:`SimConfig` (``config=...``, which carries
    ``engine`` too) or the legacy individual kwargs — passing a knob both
    ways raises. Every engine consumes the same materialized scenario
    (``events.materialize_jobs``) and returns ``events.MultiSimResult``;
    per-job chunk counts, retries, statuses and Skytrace streams are
    pinned identical across engines by tests/test_sim_engines.py.
    """
    cfg = resolve_sim_config(
        config, link_capacity_scale=link_capacity_scale,
        straggler_prob=straggler_prob, straggler_speed=straggler_speed,
        relay_buffer_chunks=relay_buffer_chunks, seed=seed,
        horizon_s=horizon_s, exec_top=exec_top, drain=drain, engine=engine,
    )
    if cfg.engine == "soa":
        from .flowsim import _simulate_multi_impl

        return _simulate_multi_impl(jobs, faults, config=cfg)
    elif cfg.engine == "ref":
        from .flowsim_ref import _simulate_multi_reference_impl

        return _simulate_multi_reference_impl(jobs, faults, config=cfg)
    elif cfg.engine == "jax":
        # lazy: the accelerator stack loads only when asked for
        from .flowsim_jax import simulate_multi_jax

        return simulate_multi_jax(jobs, faults, config=cfg)
    raise ValueError(  # unreachable: SimConfig validates eagerly
        f"unknown sim engine {cfg.engine!r}; registered engines: "
        f"{', '.join(ENGINE_NAMES)}"
    )
