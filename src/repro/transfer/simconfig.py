"""SimConfig — the one simulation-surface shape both sims consume.

``flowsim.simulate_multi`` (vectorized) and
``flowsim_ref.simulate_multi_reference`` (oracle) historically mirrored
eight keyword arguments by hand; any drift between the two signatures
silently broke the chunk-for-chunk parity the oracle exists to pin.
``SimConfig`` names that surface once:

  * both sims accept ``config=SimConfig(...)`` carrying every knob;
  * the individual kwargs remain for backward compatibility, but passing a
    knob BOTH ways is an error (no silent precedence rules);
  * ``tests/test_api_surface.py`` introspects both signatures and the
    SimConfig field set, so the oracle can never drift from the fast path
    again.

This module is import-leaf (numpy only) so both sims and ``events.py``
can use it without circularity. The registered engine NAMES live here for
the same reason: ``transfer.sim`` (the dispatcher) asserts its registry
matches ``ENGINE_NAMES``, while ``SimConfig`` can validate eagerly without
importing any engine.
"""

from __future__ import annotations

import dataclasses
import warnings

# The sanctioned simulation engines, in oracle -> fast -> accelerator order:
#   "ref" — object-per-connection oracle (flowsim_ref)
#   "soa" — vectorized numpy event loop (flowsim)
#   "jax" — fixed-shape accelerator-resident loop (flowsim_jax)
ENGINE_NAMES = ("ref", "soa", "jax")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Every knob of the multi-job data-plane simulation.

    Field defaults ARE the legacy kwarg defaults — ``SimConfig()`` is the
    exact historical behavior of calling either sim with no kwargs."""

    # shared wide-area link capacity factor (None disables link contention)
    link_capacity_scale: float | None = 2.0
    straggler_prob: float = 0.05
    straggler_speed: tuple[float, float] = (0.15, 0.5)
    relay_buffer_chunks: int = 64
    seed: int = 0
    horizon_s: float | None = None  # cut the run (jobs report "running")
    exec_top: object | None = None  # execute on a different grid (TRUE vs
    # believed — the calibration plane's split)
    drain: bool = False  # graceful horizon: in-flight chunks complete
    # which event loop runs the scenario; only transfer.sim.simulate (the
    # dispatcher) reads it — the deprecated per-engine entry points ignore
    # it by design (each IS one engine)
    engine: str = "soa"

    def __post_init__(self):
        if self.engine not in ENGINE_NAMES:
            raise ValueError(
                f"unknown sim engine {self.engine!r}; registered engines: "
                f"{', '.join(ENGINE_NAMES)}"
            )

    def replace(self, **kw) -> "SimConfig":
        return dataclasses.replace(self, **kw)


def warn_deprecated_entry(name: str) -> None:
    """One deprecation message for the per-engine sim entry points."""
    warnings.warn(
        f"{name}() is deprecated; call transfer.sim.simulate(...) with "
        'SimConfig(engine=...) or engine="..." (see README "Sim engines")',
        DeprecationWarning,
        stacklevel=3,
    )


def resolve(config: SimConfig | None, **kwargs) -> SimConfig:
    """Merge a sim's legacy kwargs with an optional ``config``.

    With no config, the kwargs build one. With a config, every legacy
    kwarg must still sit at its default — passing a knob both ways is
    ambiguous and raises rather than picking a winner silently."""
    if config is None:
        return SimConfig(**kwargs)
    ref = SimConfig()
    for k, v in kwargs.items():
        dv = getattr(ref, k)
        if not (v is dv or v == dv):
            raise ValueError(
                f"simulation knob {k!r} was passed both in SimConfig and "
                "as a keyword argument; pick one"
            )
    return config
