"""Minimal deterministic stand-in for hypothesis when it isn't installed.

Implements just the subset the test suite uses — ``@given`` with keyword
strategies, ``@settings``, ``HealthCheck``, ``st.integers``, ``st.floats``
and ``st.data()`` — by sweeping a fixed number of rng-seeded examples
(seeded per test name, so runs are reproducible). Property coverage is
narrower than real hypothesis, but the invariants still execute on every
tier-1 run instead of being skipped wholesale.
"""

from __future__ import annotations

import zlib

import numpy as np

_EXAMPLES = 5


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(None)


class _Data:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy.sample(self._rng)


class strategies:  # noqa: N801 - mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def data():
        return _DataStrategy()


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"


def settings(**_kwargs):
    def deco(fn):
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        def wrapper():
            # crc32, not hash(): str hashing is salted per process and would
            # make runs non-reproducible
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(_EXAMPLES):
                drawn = {
                    name: (_Data(rng) if isinstance(s, _DataStrategy)
                           else s.sample(rng))
                    for name, s in strategy_kwargs.items()
                }
                fn(**drawn)

        # plain zero-arg signature: pytest must not mistake the property's
        # drawn arguments for fixtures (no functools.wraps / __wrapped__)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
