import sys
from pathlib import Path

# allow `pytest tests/` without installing the package
SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: no XLA_FLAGS here on purpose — unit tests must see the real single
# CPU device. Multi-device behavior is tested in subprocesses (see
# tests/test_distributed.py) and by launch/dryrun.py.
