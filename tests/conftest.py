import sys
from pathlib import Path

import pytest

# allow `pytest tests/` without installing the package
SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: no XLA_FLAGS here on purpose — unit tests must see the real single
# CPU device. Multi-device behavior is tested in subprocesses (see
# tests/test_distributed.py) and by launch/dryrun.py.


@pytest.fixture(autouse=True)
def _reset_obs_state():
    """Zero the metrics registry and drop any enabled tracer after each
    test, so counter values never bleed across test boundaries."""
    yield
    from repro.obs import metrics, trace

    metrics.get_registry().reset()
    trace.disable()
