"""API-surface snapshots (ISSUE 7): the unified planning API is pinned.

Three layers of pinning:

  * the public ``__all__`` of the three packages — a rename or removal is
    a deliberate, test-visible act;
  * the ``PlanSpec`` / ``SimConfig`` field sets and the two simulators'
    signatures — the oracle can never silently drift from the fast path;
  * every deprecated ``plan_*`` shim returns results bitwise-equal to the
    ``Planner.plan(PlanSpec(...))`` path it delegates to.
"""

import dataclasses
import inspect

import numpy as np
import pytest

import repro.calibrate as calibrate
import repro.core as core
import repro.transfer as transfer
from repro.core import PlanSpec, Planner, default_topology
from repro.transfer.flowsim import simulate_multi
from repro.transfer.flowsim_ref import simulate_multi_reference
from repro.transfer.sim import simulate
from repro.transfer.simconfig import ENGINE_NAMES, SimConfig, resolve

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
DSTS = ("aws:eu-central-1", "gcp:us-central1")


# --------------------------------------------------------------- __all__ pins
CORE_ALL = {
    "AWS_DATASYNC", "AZURE_AZCOPY", "GBIT_PER_GB", "GCP_STORAGE_TRANSFER",
    "CloudServiceModel", "McTree", "MulticastPlan", "ParetoPoint",
    "PlanSpec", "Planner", "Region", "Topology", "TransferPlan",
    "default_topology", "direct_plan", "grid_fingerprint", "gridftp_plan",
    "ron_plan", "toy_topology",
}

TRANSFER_ALL = {
    "BackoffLadder", "BlobStore", "BreakerConfig", "BreakerTransition",
    "ChaosScenario", "Chunk", "DegradationLadder", "DirStore",
    "ExecutionReport", "FaultInjector", "FlappingLink", "FleetController",
    "FleetReport", "GatewayReport", "GrayFailure", "GrayLink", "JobReport",
    "JobSimResult", "LinkBreaker", "LinkDegrade", "LinkRestore",
    "MultiSimResult", "MulticastGatewayReport", "ObjectStore",
    "ProviderBrownout", "RegionOutage", "ReplanRecord", "Report",
    "ServiceReport", "SimConfig", "SimResult", "TenantReport", "TenantSpec",
    "TransferJob", "TransferRequest", "TransferService", "VMFailure",
    "checksum", "chunk_manifest", "chunk_object", "compile_archetypes",
    "execute_plan", "execute_service_model", "simulate", "simulate_multi",
    "simulate_multi_reference", "simulate_transfer",
    "simulate_transfer_reference", "transfer_objects",
    "transfer_objects_multicast",
}

CALIBRATE_ALL = {
    "POLICY_NAMES", "BayesianEVOIPolicy", "BeliefGrid", "BeliefSnapshot",
    "CalibratedServiceReport", "CalibratedTransferService", "Calibrator",
    "DriftEvent", "DriftModel", "EpochRoll", "EpsilonGreedyPolicy",
    "GreedyVoIPolicy", "Incident", "PolicyContext", "ProbeBudget",
    "ProbePolicy", "ProbeRecord", "ProbeRound", "RoundRobinPolicy",
    "capacity_sample_from_rates", "make_policy",
}


def test_core_all_pinned():
    assert set(core.__all__) == CORE_ALL


def test_transfer_all_pinned():
    assert set(transfer.__all__) == TRANSFER_ALL


def test_calibrate_all_pinned():
    assert set(calibrate.__all__) == CALIBRATE_ALL


def test_all_names_resolve():
    for mod in (core, transfer, calibrate):
        for name in mod.__all__:
            assert getattr(mod, name, None) is not None, \
                f"{mod.__name__}.__all__ exports missing name {name}"


# ------------------------------------------------------------ field-set pins
PLANSPEC_FIELDS = {
    "objective", "src", "dst", "dsts", "volume_gb", "tput_goal_gbps",
    "cost_ceiling_per_gb", "n_samples", "mode", "backend", "robustness",
    "degraded_links", "vm_caps", "tput_scale", "agg_scale",
}

SIMCONFIG_FIELDS = {
    "link_capacity_scale", "straggler_prob", "straggler_speed",
    "relay_buffer_chunks", "seed", "horizon_s", "exec_top", "drain",
    "engine",
}


def test_planspec_fields_pinned():
    assert {f.name for f in dataclasses.fields(PlanSpec)} == PLANSPEC_FIELDS


def test_simconfig_fields_pinned():
    assert {f.name for f in dataclasses.fields(SimConfig)} == SIMCONFIG_FIELDS


def test_sim_signatures_identical():
    """The oracle's surface IS the fast path's surface — name, kind and
    default of every parameter (the drift SimConfig exists to prevent)."""
    fast = inspect.signature(simulate_multi)
    ref = inspect.signature(simulate_multi_reference)
    assert list(fast.parameters) == list(ref.parameters)
    for name in fast.parameters:
        pf, pr = fast.parameters[name], ref.parameters[name]
        assert pf.kind == pr.kind, name
        assert pf.default == pr.default or (
            pf.default is pr.default
        ), name


def test_simconfig_knobs_cover_both_sims():
    """Every SimConfig field except ``engine`` is a keyword of both
    per-engine entry points (each IS one engine, so they take no engine
    knob); the dispatcher carries the full set."""
    for fn in (simulate_multi, simulate_multi_reference):
        params = set(inspect.signature(fn).parameters)
        assert SIMCONFIG_FIELDS - {"engine"} <= params
    assert SIMCONFIG_FIELDS <= set(inspect.signature(simulate).parameters)


def test_dispatcher_signature_is_legacy_plus_engine():
    """transfer.sim.simulate = the pinned per-engine signature plus a
    trailing ``engine`` kwarg — callers migrate by renaming the function,
    never by reshuffling arguments."""
    legacy = inspect.signature(simulate_multi)
    disp = inspect.signature(simulate)
    names = list(disp.parameters)
    assert names[:-1] == list(legacy.parameters)
    assert names[-1] == "engine"
    assert disp.parameters["engine"].default == "soa"
    for name in legacy.parameters:
        pl, pd = legacy.parameters[name], disp.parameters[name]
        assert pl.kind == pd.kind, name
        assert pl.default == pd.default or pl.default is pd.default, name


def test_engine_registry_pinned():
    assert ENGINE_NAMES == ("ref", "soa", "jax")
    assert SimConfig().engine == "soa"
    with pytest.raises(ValueError, match="unknown sim engine"):
        SimConfig(engine="numpy")
    with pytest.raises(ValueError, match="both"):
        resolve(SimConfig(engine="jax"), engine="ref")


def test_deprecated_sim_shims_bitwise_equal_dispatcher():
    """The shims warn and return results bitwise-equal to the dispatcher
    (same impl underneath — this pins the delegation wiring)."""
    from repro.core import direct_plan
    from repro.transfer import LinkDegrade, TransferJob

    top = default_topology()
    jobs = [TransferJob(direct_plan(top, SRC, DST, 0.5, num_vms=2), "a")]
    faults = [LinkDegrade(t_s=0.5, src=top.index(SRC), dst=top.index(DST),
                          factor=0.5)]
    for shim, engine in (
        (simulate_multi, "soa"), (simulate_multi_reference, "ref"),
    ):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            legacy = shim(jobs, faults, seed=1)
        fresh = simulate(jobs, faults, seed=1, engine=engine)
        assert legacy.time_s == fresh.time_s
        assert legacy.events == fresh.events
        for a, b in zip(legacy.jobs, fresh.jobs):
            assert a.time_s == b.time_s
            assert a.chunks_delivered == b.chunks_delivered
            assert a.total_cost == b.total_cost
            assert a.per_edge_gb == b.per_edge_gb


# ------------------------------------------------------- PlanSpec validation
def test_planspec_requires_exactly_one_destination():
    with pytest.raises(ValueError):
        PlanSpec(objective="cost_min", src=SRC)
    with pytest.raises(ValueError):
        PlanSpec(objective="cost_min", src=SRC, dst=DST, dsts=DSTS)


def test_planspec_rejects_unknown_objective():
    with pytest.raises(ValueError):
        PlanSpec(objective="fastest", src=SRC, dst=DST)


def test_planspec_tput_max_needs_ceiling():
    with pytest.raises(ValueError):
        PlanSpec(objective="tput_max", src=SRC, dst=DST)


def test_planspec_pareto_is_unicast_only():
    with pytest.raises(ValueError):
        PlanSpec(objective="pareto", src=SRC, dsts=DSTS)


def test_planspec_freezes_mappings_for_equality():
    a = PlanSpec(objective="cost_min", src=SRC, dst=DST, tput_goal_gbps=2.0,
                 degraded_links={(0, 1): 0.5, (2, 3): 0.1},
                 vm_caps={4: 2.0})
    b = PlanSpec(objective="cost_min", src=SRC, dst=DST, tput_goal_gbps=2.0,
                 degraded_links={(2, 3): 0.1, (0, 1): 0.5},
                 vm_caps={4: 2.0})
    assert a == b
    assert hash(a) == hash(b)
    assert a.degraded_links_map == {(0, 1): 0.5, (2, 3): 0.1}
    assert a.vm_caps_map == {4: 2.0}


def test_simconfig_both_ways_raises():
    with pytest.raises(ValueError, match="both"):
        resolve(SimConfig(seed=3), seed=5)
    # a kwarg still at its default is not a conflict
    cfg = resolve(SimConfig(seed=3), seed=0)
    assert cfg.seed == 3
    assert resolve(None, seed=5).seed == 5


# --------------------------------------------------- shim bitwise equality
@pytest.fixture(scope="module")
def planner():
    return Planner(default_topology(), max_relays=6)


def _assert_plans_equal(a, b):
    if isinstance(a, float) or np.ndim(a) == 0 and not hasattr(a, "F"):
        assert a == b
        return
    if isinstance(a, list):  # pareto frontiers
        assert len(a) == len(b)
        for pa, pb in zip(a, b):
            assert pa.tput_goal == pb.tput_goal
            assert pa.cost_per_gb == pb.cost_per_gb
            _assert_plans_equal(pa.plan, pb.plan)
        return
    grid_a = a.G if hasattr(a, "G") else a.F
    grid_b = b.G if hasattr(b, "G") else b.F
    assert np.array_equal(np.asarray(grid_a), np.asarray(grid_b))
    assert np.array_equal(np.asarray(a.N), np.asarray(b.N))
    assert a.total_cost == b.total_cost
    assert a.throughput == b.throughput


SHIM_CASES = [
    ("max_throughput", (SRC, DST), {},
     dict(objective="max_throughput", src=SRC, dst=DST)),
    ("max_multicast_throughput", (SRC, DSTS), {},
     dict(objective="max_throughput", src=SRC, dsts=DSTS)),
    ("plan_cost_min", (SRC, DST, 2.0, 4.0), {},
     dict(objective="cost_min", src=SRC, dst=DST, tput_goal_gbps=2.0,
          volume_gb=4.0)),
    ("plan_tput_max", (SRC, DST, 0.09, 4.0), {"n_samples": 8},
     dict(objective="tput_max", src=SRC, dst=DST, cost_ceiling_per_gb=0.09,
          volume_gb=4.0, n_samples=8)),
    ("plan_multicast_cost_min", (SRC, DSTS, 1.5, 4.0), {},
     dict(objective="cost_min", src=SRC, dsts=DSTS, tput_goal_gbps=1.5,
          volume_gb=4.0)),
    ("plan_multicast_tput_max", (SRC, DSTS, 0.15, 4.0), {"n_samples": 4},
     dict(objective="tput_max", src=SRC, dsts=DSTS,
          cost_ceiling_per_gb=0.15, volume_gb=4.0, n_samples=4)),
    ("pareto_frontier", (SRC, DST, 4.0), {"n_samples": 6},
     dict(objective="pareto", src=SRC, dst=DST, volume_gb=4.0,
          n_samples=6)),
    ("pareto_frontier_fast", (SRC, DST, 4.0), {"n_samples": 8},
     dict(objective="pareto_fast", src=SRC, dst=DST, volume_gb=4.0,
          n_samples=8)),
]


@pytest.mark.parametrize(
    "method,args,kwargs,spec_kw",
    SHIM_CASES, ids=[c[0] for c in SHIM_CASES],
)
def test_shim_bitwise_equals_spec_path(planner, method, args, kwargs,
                                       spec_kw):
    with pytest.warns(DeprecationWarning, match=method):
        legacy = getattr(planner, method)(*args, **kwargs)
    fresh = planner.plan(PlanSpec(**spec_kw))
    _assert_plans_equal(legacy, fresh)


# --------------------------------------------------------- report protocol
def test_report_protocol_conformance():
    """Every exported report dataclass speaks to_dict()/summary(): a kind
    tag, canonical payload keys, and declared headline fields."""
    from repro.transfer.reports import Report

    classes = [
        transfer.JobReport, transfer.ServiceReport, transfer.GatewayReport,
        transfer.MulticastGatewayReport, transfer.FleetReport,
        transfer.TenantReport, calibrate.CalibratedServiceReport,
    ]
    kinds = set()
    for cls in classes:
        assert issubclass(cls, Report), cls.__name__
        assert cls.kind != Report.kind, f"{cls.__name__} keeps default kind"
        assert cls._payload is not Report._payload, cls.__name__
        assert isinstance(cls._summary_keys, tuple) and cls._summary_keys
        kinds.add(cls.kind)
    assert len(kinds) == len(classes), "report kind tags must be unique"
