"""Calibration plane (ISSUE 4): drift model, belief grid, probe budget,
uncertainty-aware planning on cached structures, closed-loop service."""

import numpy as np
import pytest

from repro.calibrate import (
    BeliefGrid,
    CalibratedTransferService,
    Calibrator,
    DriftModel,
    Incident,
    ProbeBudget,
)
from repro.core import Planner, default_topology, milp, toy_topology
from repro.transfer import TransferRequest

SRC, DST = "aws:us-west-2", "aws:eu-central-1"


@pytest.fixture(scope="module")
def top():
    return default_topology()


# ------------------------------------------------------------------ drift
def test_drift_model_deterministic_and_pure_in_time(top):
    a = DriftModel(top, seed=7, n_incidents=3)
    b = DriftModel(top, seed=7, n_incidents=3)
    for t in (0.0, 13.25, 1e4, 123456.789):
        assert np.array_equal(a.tput_at(t), b.tput_at(t))
    # pure function of t: query order must not matter
    t1 = a.tput_at(50.0)
    a.tput_at(999.0)
    assert np.array_equal(a.tput_at(50.0), t1)
    # different seeds differ
    c = DriftModel(top, seed=8, n_incidents=3)
    assert not np.array_equal(a.tput_at(50.0), c.tput_at(50.0))


def test_drift_respects_link_mask_and_clip(top):
    d = DriftModel(top, seed=1, drift_sigma=0.4)
    g = d.tput_at(777.0)
    base = np.asarray(top.tput)
    assert (g[base == 0] == 0).all()
    live = base > 0
    ratio = g[live] / base[live]
    assert (ratio >= 0.02 - 1e-12).all() and (ratio <= 2.0 + 1e-12).all()


def test_incident_window_applies_exactly(top):
    s, d = top.index(SRC), top.index(DST)
    inc = Incident(src=s, dst=d, t_start_s=10.0, duration_s=5.0, severity=0.1)
    dm = DriftModel(top, seed=0, drift_sigma=0.0, diurnal_amp=0.0,
                    incidents=[inc])
    before, during = dm.tput_at(9.99), dm.tput_at(12.0)
    after = dm.tput_at(15.0)  # end is exclusive
    assert during[s, d] == pytest.approx(0.1 * before[s, d])
    assert after[s, d] == pytest.approx(before[s, d])
    # only the one link is touched
    mask = np.ones_like(before, dtype=bool)
    mask[s, d] = False
    assert np.array_equal(before[mask], during[mask])


def test_drift_topology_at_is_copy_on_write(top):
    dm = DriftModel(top, seed=0)
    t5 = dm.topology_at(5.0)
    assert t5 is not top
    assert np.array_equal(t5.price_egress, top.price_egress)
    assert t5._lp_struct_cache == {}  # fresh caches on the new instance


# ----------------------------------------------------------------- belief
def test_belief_updates_tighten_and_move_mean(top):
    s, d = top.index(SRC), top.index(DST)
    bel = BeliefGrid(top)
    g0 = bel.mean[s, d]
    se0 = bel.stderr()[s, d]
    for _ in range(6):
        bel.observe(s, d, 0.9 * g0, weight=1.0)
    assert bel.mean[s, d] < g0
    assert bel.stderr()[s, d] < se0
    assert bel.lower_bound(1.5)[s, d] <= bel.mean[s, d]


def test_belief_change_point_reset(top):
    s, d = top.index(SRC), top.index(DST)
    bel = BeliefGrid(top)
    g0 = bel.mean[s, d]
    # a collapsed measurement far outside the band resets, not averages
    was_reset = bel.observe_adaptive(s, d, 0.05 * g0, weight=1.0)
    assert was_reset
    assert bel.mean[s, d] == pytest.approx(0.05 * g0)
    # an in-band follow-up folds in normally
    was_reset = bel.observe_adaptive(s, d, 0.052 * g0, weight=1.0)
    assert not was_reset


def test_belief_scale_grid_clips_and_rides_lcb(top):
    s, d = top.index(SRC), top.index(DST)
    bel = BeliefGrid(top)
    phi0 = bel.scale_grid(top, z=1.5)
    assert (phi0 <= 1.0).all() and (phi0 >= 0.02).all()
    bel.reset_link(s, d, 0.1 * top.tput[s, d])
    phi = bel.scale_grid(top, z=1.5)
    assert phi[s, d] < phi0[s, d]
    assert phi[s, d] == pytest.approx(
        max(bel.lower_bound(1.5)[s, d] / top.tput[s, d], 0.02)
    )


# ------------------------------------------------- robust planning (cached)
def test_robust_plan_zero_struct_builds_and_respects_cuts(top):
    """Acceptance: robustness rides the cached LPStructure — zero
    re-assemblies — and the robust plan obeys both the tightened 4b row
    and the aggregate interconnect cap of the scaled link."""
    s, d = top.index(SRC), top.index(DST)
    bel = BeliefGrid(top)
    pl = Planner(top, max_relays=6, belief=bel, link_capacity_scale=2.0)
    base = pl.plan_cost_min(SRC, DST, 3.0, 4.0)  # builds + caches structures
    assert base.solver_status == "optimal"
    bel.reset_link(s, d, 0.1 * top.tput[s, d])
    builds0 = milp.N_STRUCT_BUILDS
    robust = pl.plan_cost_min(SRC, DST, 3.0, 4.0, robustness=1.5)
    assert milp.N_STRUCT_BUILDS == builds0, "robust plan re-assembled an LP"
    assert robust.solver_status == "optimal"
    phi = bel.scale_grid(top, z=1.5)[s, d]
    # tightened 4b on the scaled link
    cap_4b = phi * top.tput[s, d] * robust.M[s, d] / top.limit_conn
    assert robust.F[s, d] <= cap_4b + 1e-6
    # aggregate interconnect cap: more VMs cannot buy the incident back
    assert robust.F[s, d] <= phi * top.tput[s, d] * 2.0 + 1e-6
    # base constraints still hold
    assert robust.validate() == []


def test_robustness_requires_belief(top):
    pl = Planner(top, max_relays=6)
    with pytest.raises(ValueError, match="belief"):
        pl.plan_cost_min(SRC, DST, 2.0, 4.0, robustness=1.0)


def test_robust_tput_max_under_scaled_grid(top):
    s, d = top.index(SRC), top.index(DST)
    bel = BeliefGrid(top)
    pl = Planner(top, max_relays=6, belief=bel, link_capacity_scale=2.0)
    bel.reset_link(s, d, 0.2 * top.tput[s, d])
    plan = pl.plan_tput_max(SRC, DST, 0.25, 4.0, n_samples=8, robustness=1.5)
    assert plan.solver_status in ("optimal", "cost_ceiling_infeasible")
    phi = bel.scale_grid(top, z=1.5)[s, d]
    assert plan.F[s, d] <= phi * top.tput[s, d] * 2.0 + 1e-6


def test_robust_multicast_zero_builds(top):
    src = "gcp:us-central1"
    dsts = ["gcp:europe-west1", "gcp:europe-west3"]
    bel = BeliefGrid(top)
    pl = Planner(top, max_relays=6, belief=bel, link_capacity_scale=2.0)
    base = pl.plan_multicast_cost_min(src, dsts, 1.0, 4.0)
    assert base.solver_status == "optimal"
    s = top.index(src)
    d0 = top.index(dsts[0])
    bel.reset_link(s, d0, 0.1 * top.tput[s, d0])
    builds0 = milp.N_STRUCT_BUILDS
    robust = pl.plan_multicast_cost_min(src, dsts, 1.0, 4.0, robustness=1.5)
    assert milp.N_STRUCT_BUILDS == builds0
    assert robust.solver_status == "optimal"
    phi = bel.scale_grid(top, z=1.5)[s, d0]
    assert robust.G[s, d0] <= phi * top.tput[s, d0] * 2.0 + 1e-6


# -------------------------------------------------------------- calibrator
def test_probe_budget_is_respected(top):
    bel = BeliefGrid(top)
    pl = Planner(top, max_relays=6)
    cal = Calibrator(bel, budget=ProbeBudget(
        usd_per_round=0.05, seconds_per_round=30.0, max_probes_per_round=4,
    ))
    dm = DriftModel(top, seed=3)
    rnd = cal.run_round(0.0, dm.tput_at(0.0), planner=pl,
                        contexts=[(SRC, DST)])
    assert rnd.cost_usd <= 0.05 + 1e-12
    assert rnd.n_probes <= 4
    assert rnd.n_probes > 0
    for r in rnd.records:
        assert r.cost_usd > 0 and r.duration_s <= 30.0


def test_probe_targeting_prefers_plan_links(top):
    bel = BeliefGrid(top)
    pl = Planner(top, max_relays=6)
    plan = pl.plan_cost_min(SRC, DST, 3.0, 4.0)
    cal = Calibrator(bel)
    links = cal.candidate_links(pl, [(SRC, DST)])
    scores = cal.score_links(links, plans=[plan], t_s=0.0)
    on_plan = [i for i, (a, b) in enumerate(links) if plan.F[a, b] > 1e-9]
    off_plan = [i for i, (a, b) in enumerate(links) if plan.F[a, b] <= 1e-9]
    assert on_plan and off_plan
    # uncertainty/staleness are uniform at t=0, so plan links must lead
    assert max(scores[on_plan]) > max(scores[off_plan])


def test_belief_error_shrinks_monotonically_over_probe_rounds(top):
    """Acceptance: believed-vs-true grid error over the candidate links
    shrinks monotonically across probe rounds in a pinned scenario (static
    truth, noiseless probes)."""
    dm = DriftModel(top, seed=11, drift_sigma=0.3, diurnal_amp=0.0)
    true_grid = dm.tput_at(500.0)  # frozen snapshot, well off the prior
    bel = BeliefGrid(top)
    pl = Planner(top, max_relays=6)
    cal = Calibrator(bel, noise_sigma=0.0,
                     budget=ProbeBudget(usd_per_round=2.0,
                                        seconds_per_round=60.0,
                                        max_probes_per_round=6))
    errs = []
    for k in range(8):
        rnd = cal.run_round(float(k), true_grid, planner=pl,
                            contexts=[(SRC, DST)])
        errs.append(rnd.belief_error)
    assert all(e1 <= e0 + 1e-12 for e0, e1 in zip(errs, errs[1:])), errs
    assert errs[-1] < 0.5 * errs[0]


# ---------------------------------------------------------- closed loop
def test_calibrated_service_survives_step_change_incident(top):
    """Acceptance core: a long transfer across a step-change incident.
    The calibrated service detects the drift, re-plans the REMAINING
    volume around the collapsed link with zero LP re-assembly, and
    delivers >= 1.5x the stale-grid service's throughput."""
    s, d = top.index(SRC), top.index(DST)
    drift = DriftModel(top, seed=0, drift_sigma=0.10, diurnal_amp=0.0,
                       incidents=[Incident(src=s, dst=d, t_start_s=6.0,
                                           duration_s=1e9, severity=0.08)])
    achieved = {}
    reports = {}
    for calibrate in (True, False):
        svc = CalibratedTransferService(
            drift, backend="jax", max_relays=6, calibrate=calibrate,
            check_interval_s=4.0, max_segments=120,
        )
        svc.submit(TransferRequest("big", SRC, DST, 8.0, 4.0))
        rep = svc.run()
        j = rep.jobs[0]
        assert j.status == "done", (calibrate, j.status)
        achieved[calibrate] = j.delivered_gb * 8.0 / rep.time_s
        reports[calibrate] = rep
    cal, stale = reports[True], reports[False]
    assert cal.drift_events, "the incident must be detected"
    assert cal.replans, "detection must trigger re-planning"
    for r in cal.replans:
        assert r.structure_builds == 0, "robust re-plan re-assembled an LP"
        assert r.plan.solver_status == "optimal"
    assert not stale.replans and not stale.drift_events
    assert achieved[True] >= 1.5 * achieved[False], achieved
    # the re-planned allocation routes around the collapsed link
    final = cal.replans[-1].plan
    assert final.F[s, d] <= 0.25 * cal.jobs[0].request.tput_goal_gbps


def test_calibrated_service_no_drift_no_replans(top):
    """On a quiet topology (no incidents, tiny drift) the loop should not
    thrash: no drift events, no re-plans, job completes near plan."""
    drift = DriftModel(top, seed=5, drift_sigma=0.01, diurnal_amp=0.0)
    svc = CalibratedTransferService(drift, backend="jax", max_relays=6,
                                    check_interval_s=4.0)
    svc.submit(TransferRequest("calm", SRC, DST, 4.0, 3.0))
    rep = svc.run()
    j = rep.jobs[0]
    assert j.status == "done"
    assert not rep.drift_events and not rep.replans
    assert j.delivered_gb == pytest.approx(4.0, rel=0.02)


def test_calibrated_service_runs_multicast_jobs(top):
    """The loop is job-flavor agnostic: a one-to-many replication rides
    the same probe/harvest/detect machinery (envelope G as the expected
    per-link rate) and completes on the drifting true topology."""
    drift = DriftModel(top, seed=4, drift_sigma=0.02, diurnal_amp=0.0)
    svc = CalibratedTransferService(drift, backend="jax", max_relays=6,
                                    check_interval_s=4.0)
    svc.submit(TransferRequest(
        "repl", "gcp:us-central1", "", 3.0, 1.5,
        dsts=["gcp:europe-west1", "gcp:europe-west3"],
    ))
    rep = svc.run()
    j = rep.jobs[0]
    assert j.status == "done"
    assert j.delivered_gb == pytest.approx(3.0, rel=0.02)
    assert rep.probe_rounds  # the calibrator ran against the mc subgraph


def test_calibrated_service_rejects_scripted_faults(top):
    drift = DriftModel(top, seed=0)
    svc = CalibratedTransferService(drift)
    svc.submit(TransferRequest("x", SRC, DST, 1.0, 2.0))
    from repro.transfer import LinkDegrade
    with pytest.raises(ValueError, match="DriftModel"):
        svc.run(faults=[LinkDegrade(t_s=1.0, src=0, dst=1, factor=0.5)])


def test_probe_spend_accounted(top):
    s, d = top.index(SRC), top.index(DST)
    drift = DriftModel(top, seed=2, drift_sigma=0.05, diurnal_amp=0.0)
    svc = CalibratedTransferService(drift, backend="jax", max_relays=6,
                                    check_interval_s=4.0)
    svc.submit(TransferRequest("probe-bill", SRC, DST, 4.0, 3.0))
    rep = svc.run()
    assert rep.probe_rounds
    assert rep.probe_cost_usd > 0
    assert rep.probe_cost_usd == pytest.approx(
        sum(r.cost_usd for r in rep.probe_rounds)
    )
    for rnd in rep.probe_rounds:
        assert rnd.cost_usd <= svc.calibrator.budget.usd_per_round + 1e-12


# ------------------------------------------------------ gateway telemetry
def test_gateway_reports_link_rates_and_belief_consumes_them():
    """The real-bytes gateway exposes per-edge bytes/seconds; the belief
    folds the observed rates in through the same change-point path as
    simulator telemetry."""
    from repro.transfer import BlobStore, transfer_objects

    top = toy_topology(n=5, seed=2)
    pl = Planner(top, max_relays=3)
    plan = pl.plan_cost_min("toy:r0", "toy:r1", 2.0, 0.02)
    rng = np.random.default_rng(0)
    src_store, dst_store = BlobStore(), BlobStore()
    src_store.put("obj", rng.bytes(1_500_000))
    rep = transfer_objects(plan, src_store, dst_store, ["obj"],
                           chunk_bytes=1 << 17, workers_per_hop=2)
    assert rep.per_edge_bytes and rep.per_edge_seconds
    assert sum(rep.per_edge_bytes.values()) == rep.bytes_moved
    plan_edges = {(a, b) for a in range(top.num_regions)
                  for b in range(top.num_regions) if plan.F[a, b] > 1e-9}
    assert set(rep.per_edge_bytes) <= plan_edges
    rates = rep.link_gbps()
    assert rates and all(g > 0 for g in rates.values())
    bel = BeliefGrid(top)
    n = bel.observe_link_rates(rates, weight=1.0, t_s=1.0, one_sided=False)
    assert n == len(rates)
    for (a, b) in rates:
        assert bel.last_obs_t[a, b] == 1.0
    # the default one-sided feed drops below-mean samples: a hop throttled
    # by an upstream bottleneck must not reset a healthy link's belief low
    bel2 = BeliefGrid(top)
    (a, b) = next(iter(rates))
    low = {(a, b): 0.01 * bel2.mean[a, b]}
    assert bel2.observe_link_rates(low, t_s=2.0) == 0
    assert bel2.mean[a, b] == BeliefGrid(top).mean[a, b]


# --------------------------------------------------------- drain semantics
def test_drain_mode_completes_in_flight_chunks():
    """A hard horizon cut discards in-flight chunks; drain finishes them.
    On a slow link whose per-chunk ETA exceeds the horizon, only the
    drained run makes progress — the mechanism that lets the calibrated
    service segment its timeline without Zeno-stalling slow links."""
    from repro.transfer import TransferJob
    from repro.transfer.flowsim import simulate_multi

    top = toy_topology(n=5, seed=2)
    pl = Planner(top, max_relays=3)
    plan = pl.plan_cost_min("toy:r0", "toy:r1", 1.0, 0.05)
    job = TransferJob(plan=plan, name="slow", chunk_mb=16.0)
    # execute on a 50x-degraded true grid: per-chunk ETA >> horizon
    exec_top = top.with_tput(scale=0.02)
    hard = simulate_multi([job], (), seed=0, horizon_s=0.5,
                          exec_top=exec_top)
    soft = simulate_multi([job], (), seed=0, horizon_s=0.5,
                          exec_top=exec_top, drain=True)
    assert hard.jobs[0].chunks_delivered == 0
    assert soft.jobs[0].chunks_delivered > 0
    assert soft.time_s > 0.5  # the drain runs past the horizon
