"""Chaos plane: correlated fault archetypes, deadline SLOs, retry budgets,
and the link circuit breaker — plus the invariants they must keep: zero
delivered-byte loss, chunk-for-chunk sim parity for every new event type,
and cached-structure re-plans (``milp.N_STRUCT_BUILDS`` pinned)."""

import numpy as np
import pytest

from repro.core import default_topology, direct_plan, milp
from repro.transfer import (
    BackoffLadder,
    BreakerConfig,
    ChaosScenario,
    DegradationLadder,
    FlappingLink,
    GrayFailure,
    GrayLink,
    LinkBreaker,
    LinkDegrade,
    LinkRestore,
    ProviderBrownout,
    RegionOutage,
    TransferJob,
    TransferRequest,
    TransferService,
    VMFailure,
    compile_archetypes,
    simulate_multi,
    simulate_multi_reference,
)

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "gcp:us-central1"


@pytest.fixture(scope="module")
def top():
    return default_topology()


def _jobs(top, volume=2.0):
    return [
        TransferJob(direct_plan(top, SRC, DST, volume, num_vms=2), "a",
                    arrival_s=0.0),
        TransferJob(direct_plan(top, SRC, DST, volume, num_vms=2), "b",
                    arrival_s=1.0),
        TransferJob(direct_plan(top, SRC2, DST, volume, num_vms=2), "c",
                    arrival_s=0.5),
    ]


def _service(top, **kw):
    kw.setdefault("backend", "jax")
    kw.setdefault("max_relays", 6)
    return TransferService(top, **kw)


def _assert_parity(new, ref):
    for a, b in zip(new.jobs, ref.jobs):
        assert a.chunks_delivered == b.chunks_delivered
        assert a.retried_chunks == b.retried_chunks
        assert a.status == b.status
        assert a.tput_gbps == pytest.approx(b.tput_gbps, rel=1e-9)
        assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert new.time_s == pytest.approx(ref.time_s, rel=1e-9)


# ----------------------------------------------------------- scenario purity
def test_chaos_scenario_is_pure_function_of_seed(top):
    kw = dict(seed=7, horizon_s=12.0, n_region_outages=1, n_brownouts=1,
              n_gray=2, n_flapping=2)
    a = ChaosScenario(top, **kw)
    b = ChaosScenario(top, **kw)
    assert a.archetypes == b.archetypes
    assert a.events(3) == b.events(3)
    # a different seed draws a different scenario
    c = ChaosScenario(top, **{**kw, "seed": 8})
    assert c.archetypes != a.archetypes


def test_chaos_scenario_archetype_mix_and_ordering(top):
    sc = ChaosScenario(top, seed=3, n_region_outages=1, n_brownouts=1,
                       n_gray=2, n_flapping=1)
    kinds = sorted(type(a).__name__ for a in sc.archetypes)
    assert kinds == ["FlappingLink", "GrayLink", "GrayLink",
                     "ProviderBrownout", "RegionOutage"]
    ts = [a.t_s for a in sc.archetypes]
    assert ts == sorted(ts)
    evs = sc.events(2)
    assert [e.t_s for e in evs] == sorted(e.t_s for e in evs)


def test_compile_region_outage_kills_vms_and_collapses_links(top):
    s = top.index(SRC)
    evs = compile_archetypes(
        [RegionOutage(t_s=2.0, region=s, duration_s=4.0, severity=0.05)],
        top, n_jobs=2,
    )
    vmf = [e for e in evs if isinstance(e, VMFailure)]
    assert {e.job for e in vmf} == {0, 1}
    assert all(e.region == s and e.count >= top.limit_vm for e in vmf)
    downs = [e for e in evs if isinstance(e, LinkDegrade)]
    ups = [e for e in evs if isinstance(e, LinkRestore)]
    assert len(downs) == len(ups) > 0
    assert all(e.src == s or e.dst == s for e in downs)
    # every down/up pair compounds back to exactly 1.0
    for dn, up in zip(sorted(downs, key=lambda e: (e.src, e.dst)),
                      sorted(ups, key=lambda e: (e.src, e.dst))):
        assert dn.factor * up.factor == pytest.approx(1.0)
        assert up.t_s == pytest.approx(dn.t_s + 4.0)


def test_compile_brownout_scopes_to_provider(top):
    evs = compile_archetypes(
        [ProviderBrownout(t_s=1.0, provider="gcp", duration_s=3.0,
                          severity=0.5)],
        top, n_jobs=1,
    )
    keys = top.keys()
    for e in evs:
        assert keys[e.src].startswith("gcp:") or keys[e.dst].startswith("gcp:")


def test_compile_gray_and_flapping(top):
    s, d = top.index(SRC), top.index(DST)
    evs = compile_archetypes(
        [GrayLink(t_s=1.0, src=s, dst=d, duration_s=5.0,
                  delivered_fraction=0.25),
         FlappingLink(t_s=2.0, src=s, dst=d, n_flaps=3, period_s=2.0,
                      down_factor=0.1, duty=0.5)],
        top, n_jobs=1,
    )
    grays = [e for e in evs if isinstance(e, GrayFailure)]
    assert len(grays) == 2  # down + silent recovery
    assert grays[0].factor * grays[1].factor == pytest.approx(1.0)
    downs = [e for e in evs if isinstance(e, LinkDegrade)]
    ups = [e for e in evs if isinstance(e, LinkRestore)]
    assert len(downs) == len(ups) == 3
    with pytest.raises(TypeError):
        compile_archetypes([object()], top, n_jobs=1)


# --------------------------------------------------------------- sim parity
@pytest.mark.parametrize("seed", [0, 3])
def test_new_event_types_match_reference(top, seed):
    """Acceptance: GrayFailure and LinkRestore execute chunk-for-chunk
    identically in the vectorized loop and the object-per-connection
    oracle — including compounding down/up cycles."""
    s, d = top.index(SRC), top.index(DST)
    jobs = _jobs(top)
    faults = [
        GrayFailure(t_s=0.5, src=s, dst=d, factor=0.3),
        LinkDegrade(t_s=1.0, src=s, dst=d, factor=0.5),
        LinkRestore(t_s=2.0, src=s, dst=d, factor=2.0),
        GrayFailure(t_s=2.5, src=s, dst=d, factor=1.0 / 0.3),
        LinkDegrade(t_s=3.0, src=top.index(SRC2), dst=d, factor=0.1),
        LinkRestore(t_s=4.0, src=top.index(SRC2), dst=d, factor=10.0),
    ]
    _assert_parity(simulate_multi(jobs, faults, seed=seed),
                   simulate_multi_reference(jobs, faults, seed=seed))


@pytest.mark.parametrize("seed", [5, 11])
def test_chaos_scenario_parity_and_zero_loss(top, seed):
    """A full seeded chaos suite — outage + brownout + gray + flapping —
    stays chunk-for-chunk identical across both simulators, and every
    delivered count is exact (no loss, no duplicates)."""
    s, d, s2 = top.index(SRC), top.index(DST), top.index(SRC2)
    jobs = _jobs(top)
    sc = ChaosScenario(top, seed=seed, horizon_s=8.0, n_region_outages=1,
                       n_brownouts=1, n_gray=1, n_flapping=1,
                       links=[(s, d), (s2, d)])
    faults = sc.events(len(jobs))
    new = simulate_multi(jobs, faults, seed=seed)
    ref = simulate_multi_reference(jobs, faults, seed=seed)
    _assert_parity(new, ref)
    for j in new.jobs:
        if j.status == "done":
            assert j.chunks_delivered == j.n_chunks
        assert j.chunks_delivered <= j.n_chunks


# ------------------------------------------------------------ backoff ladder
def test_backoff_ladder_sequence_pinned(top):
    """Satellite: the re-plan goal ladder is named, configurable data —
    and the exact goal sequence attempted is observable."""
    assert BackoffLadder().factors == (1.0, 0.5, 0.25)
    assert BackoffLadder().goals(8.0) == [8.0, 4.0, 2.0]
    ladder = BackoffLadder(name="steep", factors=(1.0, 0.1))
    svc = _service(top, backoff_ladder=ladder)
    svc.submit(TransferRequest("j", SRC, DST, 2.0, 2.0))
    s, d = top.index(SRC), top.index(DST)

    tried = []
    orig = svc._plan_for

    def spy(req, goal, volume_gb, **kw):
        if kw.get("constrained"):
            tried.append(float(np.max(goal)))
            plan = orig(req, goal, volume_gb, **kw)
            plan.solver_status = "infeasible"  # force the full walk
            return plan
        return orig(req, goal, volume_gb, **kw)

    svc._plan_for = spy
    rep = svc.run(faults=[LinkDegrade(t_s=1.0, src=s, dst=d, factor=0.5)])
    rec = rep.jobs[0].replans[0]
    assert rec.ladder == "steep"
    assert rec.backoffs == len(ladder.factors) - 1
    assert len(tried) == 2
    assert tried[1] == pytest.approx(tried[0] * 0.1)


def test_default_ladder_replan_matches_legacy_first_rung(top):
    """The default ladder's first rung re-plans exactly like the old
    hardcoded loop: full goal, zero backoffs, cached structures."""
    svc = _service(top)
    svc.submit(TransferRequest("j", SRC, DST, 2.0, 2.0))
    s, d = top.index(SRC), top.index(DST)
    rep = svc.run(faults=[LinkDegrade(t_s=2.0, src=s, dst=d, factor=0.5)])
    (rec,) = rep.jobs[0].replans
    assert rec.ladder == "halving"
    assert rec.reason == "fault"
    assert rec.backoffs == 0 and not rec.degraded_slo
    assert rec.structure_builds == 0


# ---------------------------------------------------------- failure policies
def test_retry_budget_zero_fails_fast_report_intact(top):
    """Satellite: budget 0 means the first restarted chunk tips the job to
    an explicit partial delivery — delivered bytes reported, nothing lost.

    A VM kill mid-flight cuts the segment; chunks in flight at the cut
    restart under the new plan and count against the budget."""
    svc = _service(top)
    svc.submit(TransferRequest("rb", SRC, DST, 4.0, 2.0, retry_budget=0))
    s, d = top.index(SRC), top.index(DST)
    rep = svc.run(faults=[
        VMFailure(t_s=1.0, job=0, region=s, count=2),
        LinkDegrade(t_s=2.0, src=s, dst=d, factor=0.9),
    ])
    j = rep.jobs[0]
    assert j.retried_chunks > 0
    assert j.status == "partial"
    assert j.budget_exhausted
    assert 0 <= j.delivered_chunks < j.n_chunks
    assert j.delivered_gb == pytest.approx(
        j.delivered_chunks * j.request.chunk_mb / 1024.0
    )
    assert not rep.all_done


def test_unlimited_budget_same_fault_completes(top):
    svc = _service(top)
    svc.submit(TransferRequest("ub", SRC, DST, 4.0, 2.0))
    s, d = top.index(SRC), top.index(DST)
    rep = svc.run(faults=[
        VMFailure(t_s=1.0, job=0, region=s, count=2),
        LinkDegrade(t_s=2.0, src=s, dst=d, factor=0.9),
    ])
    j = rep.jobs[0]
    assert j.status == "done"
    assert j.delivered_chunks == j.n_chunks
    assert j.retried_chunks > 0  # same fault, same restarts — just absorbed
    assert not j.budget_exhausted


def test_no_deadline_semantics_unchanged(top):
    """Satellite: deadline_s=None never escalates, never cuts partial,
    and reports deadline_met=None even with a degradation ladder armed."""
    faults_of = lambda: [  # noqa: E731
        LinkDegrade(t_s=1.0, src=top.index(SRC), dst=top.index(DST),
                    factor=0.4),
        LinkDegrade(t_s=2.0, src=top.index(SRC), dst=top.index(DST),
                    factor=0.9),
    ]
    svc_plain = _service(top)
    svc_plain.submit(TransferRequest("n", SRC, DST, 4.0, 2.0))
    rep_plain = svc_plain.run(faults=faults_of())
    svc_ladder = _service(top, degradation=DegradationLadder())
    svc_ladder.submit(TransferRequest("n", SRC, DST, 4.0, 2.0))
    rep_ladder = svc_ladder.run(faults=faults_of())
    for rep in (rep_plain, rep_ladder):
        j = rep.jobs[0]
        assert j.status == "done"
        assert j.deadline_met is None
        assert j.degrade_level == 0
    assert rep_ladder.jobs[0].delivered_chunks == \
        rep_plain.jobs[0].delivered_chunks
    assert rep_plain.slo_violation_rate == 0.0


def test_deadline_pressure_climbs_ladder_then_cuts_partial(top):
    """An impossible deadline walks shed_robustness -> shed_trickle ->
    partial; the partial report keeps exact delivered counts."""
    svc = _service(top, degradation=DegradationLadder())
    svc.submit(TransferRequest("dl", SRC, DST, 8.0, 2.0, deadline_s=2.5))
    s, d = top.index(SRC), top.index(DST)
    rep = svc.run(faults=[
        LinkDegrade(t_s=1.0, src=s, dst=d, factor=0.3),
        LinkDegrade(t_s=2.0, src=s, dst=d, factor=0.9),
        LinkDegrade(t_s=3.0, src=s, dst=d, factor=0.9),
    ])
    j = rep.jobs[0]
    assert j.status == "partial"
    assert j.deadline_met is False
    assert j.degrade_level >= 1
    assert "deadline" in {r.reason for r in j.replans}
    assert all(r.structure_builds == 0 for r in j.replans)
    assert rep.slo_violation_rate == 1.0
    assert rep.partial_jobs == [j]
    assert not rep.all_done


def test_generous_deadline_met_without_escalation(top):
    svc = _service(top, degradation=DegradationLadder())
    svc.submit(TransferRequest("ok", SRC, DST, 2.0, 2.0, deadline_s=500.0))
    s, d = top.index(SRC), top.index(DST)
    rep = svc.run(faults=[LinkDegrade(t_s=2.0, src=s, dst=d, factor=0.5)])
    j = rep.jobs[0]
    assert j.status == "done"
    assert j.deadline_met is True
    assert j.degrade_level == 0
    assert rep.slo_violation_rate == 0.0


def test_gray_failure_is_invisible_to_the_control_plane(top):
    """A GrayFailure slows the data plane but creates no boundary, no
    degraded view, no re-plan — the defining asymmetry vs LinkDegrade."""
    s, d = top.index(SRC), top.index(DST)
    svc = _service(top)
    svc.submit(TransferRequest("g", SRC, DST, 2.0, 2.0))
    rep = svc.run(faults=[GrayFailure(t_s=1.0, src=s, dst=d, factor=0.3)])
    clean = _service(top)
    clean.submit(TransferRequest("g", SRC, DST, 2.0, 2.0))
    rep_clean = clean.run()
    assert rep.segments == 1  # silent events do not segment the timeline
    assert rep.replans == []
    assert svc.degraded_links == {}
    assert rep.time_s > rep_clean.time_s  # ...but the bytes felt it
    assert rep.jobs[0].status == "done"
    # the gray view persists across visible boundaries too
    svc2 = _service(top)
    svc2.submit(TransferRequest("g2", SRC, DST, 2.0, 2.0))
    rep2 = svc2.run(faults=[
        GrayFailure(t_s=0.5, src=s, dst=d, factor=0.3),
        LinkDegrade(t_s=1.5, src=top.index(SRC2), dst=d, factor=0.5),
    ])
    assert svc2._gray == {(s, d): pytest.approx(0.3)}
    assert rep2.jobs[0].status == "done"


# ----------------------------------------------------------- circuit breaker
def test_breaker_state_machine():
    br = LinkBreaker(BreakerConfig(k=3, window_s=10.0, cooldown_s=5.0))
    L = (1, 2)
    assert not br.record_failure(L, 0.0)
    assert not br.record_failure(L, 1.0)
    assert br.record_failure(L, 2.0)  # k-th failure in window: opens
    assert br.is_quarantined(L)
    assert not br.record_failure(L, 3.0)  # already open: no re-trip
    assert br.due_half_open(4.0) == []
    assert br.due_half_open(7.5) == [L]
    assert br.is_quarantined(L)  # half-open still blocks tenant traffic
    br.half_open_result(L, 7.5, healthy=False)
    assert br.is_quarantined(L)
    assert br.due_half_open(13.0) == [L]
    br.half_open_result(L, 13.0, healthy=True)
    assert not br.is_quarantined(L)
    assert br.trips == 1
    assert [t.state for t in br.transitions] == \
        ["open", "half_open", "open", "half_open", "closed"]


def test_breaker_window_evicts_stale_failures():
    br = LinkBreaker(k=3, window_s=2.0)
    L = (0, 1)
    br.record_failure(L, 0.0)
    br.record_failure(L, 0.5)
    assert not br.record_failure(L, 5.0)  # first two aged out
    assert not br.is_quarantined(L)
    with pytest.raises(ValueError):
        LinkBreaker(k=0)


def _flap_faults(s, d, n=4, t0=1.0, period=1.0):
    out = []
    for i in range(n):
        t = t0 + i * period
        out.append(LinkDegrade(t_s=t, src=s, dst=d, factor=0.05))
        out.append(LinkRestore(t_s=t + 0.5, src=s, dst=d, factor=20.0))
    return out


def test_quarantined_link_gets_zero_chunks_in_both_sims(top):
    """Regression: once the breaker opens on the flapping trunk, NO chunk
    rides it — in the vectorized simulator AND the reference oracle —
    while the job still completes over the re-planned routes, all on
    cached structures."""
    s, d = top.index(SRC), top.index(DST)
    results = {}
    for sim_name, sim_fn in (("vec", simulate_multi),
                             ("ref", simulate_multi_reference)):
        seen = []
        svc = None

        def spy_sim(jobs, faults, **kw):
            res = sim_fn(jobs, faults, **kw)
            seen.append((dict(svc.degraded_links), res))
            return res

        br = LinkBreaker(BreakerConfig(k=3, window_s=30.0, cooldown_s=60.0))
        svc = _service(top, breaker=br)
        svc.submit(TransferRequest("f", SRC, DST, 4.0, 2.0))
        svc._admit(svc._queue[0])  # warm the planner's structure cache
        builds0 = milp.N_STRUCT_BUILDS
        rep = svc.run(faults=_flap_faults(s, d), sim=spy_sim)
        # admission re-used the warmed structures and every quarantine
        # re-plan rode them as extra_ub scale cuts: zero re-assembly
        assert milp.N_STRUCT_BUILDS == builds0
        assert br.is_quarantined((s, d))
        # every segment simulated while the view pinned the link at 0.0
        # put ZERO bytes on it — the quarantine really starves the trunk
        key = f"{s}->{d}"
        gated = [res for view, res in seen if view.get((s, d)) == 0.0]
        assert gated, "breaker never opened before a simulated segment"
        for res in gated:
            for jr in res.jobs:
                assert jr.per_edge_gb.get(key, 0.0) == 0.0
        j = rep.jobs[0]
        assert j.status == "done"
        assert j.delivered_chunks == j.n_chunks  # zero loss through chaos
        assert all(r.structure_builds == 0 for r in j.replans)
        assert any(q.state == "open" for q in rep.quarantines)
        results[sim_name] = j.delivered_chunks
        # the re-planned allocation itself carries nothing on the link
        # (sub-epsilon LP dust is below the path compiler's flow floor)
        assert float(np.asarray(j.plan.F)[s, d]) < 1e-6
    assert results["vec"] == results["ref"]


def test_breaker_half_open_closes_after_quiet_restore(top):
    """Cooldown elapses, the restore seen while open counts as health, the
    breaker closes and the link returns to the plannable view."""
    s, d = top.index(SRC), top.index(DST)
    br = LinkBreaker(BreakerConfig(k=2, window_s=30.0, cooldown_s=2.0))
    svc = _service(top, breaker=br)
    svc.submit(TransferRequest("h", SRC, DST, 6.0, 2.0))
    faults = [
        LinkDegrade(t_s=1.0, src=s, dst=d, factor=0.05),
        LinkDegrade(t_s=1.5, src=s, dst=d, factor=0.9),  # 2nd: opens
        LinkRestore(t_s=2.0, src=s, dst=d, factor=1.0 / 0.045),
        LinkDegrade(t_s=5.0, src=top.index(SRC2), dst=d, factor=0.99),
    ]
    rep = svc.run(faults=faults)
    assert not br.is_quarantined((s, d))
    assert (s, d) not in svc.degraded_links  # fully healed + unquarantined
    states = [t.state for t in rep.quarantines]
    assert states == ["open", "half_open", "closed"]
    assert "quarantine" in {r.reason for r in rep.jobs[0].replans}
    assert rep.jobs[0].status == "done"


def test_chaos_soak_scenarios_zero_loss(top):
    """Soak (marked slow): seeded chaos suites across breaker configs —
    every terminal job accounts for every chunk, nothing silently lost."""
    pytest.importorskip("numpy")
    s, d, s2 = top.index(SRC), top.index(DST), top.index(SRC2)
    for seed in range(4):
        sc = ChaosScenario(top, seed=seed, horizon_s=10.0,
                           n_brownouts=seed % 2, n_gray=1, n_flapping=1,
                           links=[(s, d), (s2, d)])
        br = LinkBreaker(BreakerConfig(k=3, window_s=20.0, cooldown_s=5.0))
        svc = _service(top, breaker=br, degradation=DegradationLadder())
        svc.submit(TransferRequest("a", SRC, DST, 2.0, 2.0,
                                   deadline_s=60.0))
        svc.submit(TransferRequest("b", SRC2, DST, 2.0, 2.0, arrival_s=1.0))
        rep = svc.run(faults=sc.events(2))
        for j in rep.jobs:
            assert j.lost_chunks == 0
            assert j.delivered_chunks <= j.n_chunks
            if j.status == "done":
                assert j.delivered_chunks == j.n_chunks
        assert all(r.structure_builds == 0 for r in rep.replans)


test_chaos_soak_scenarios_zero_loss = pytest.mark.slow(
    test_chaos_soak_scenarios_zero_loss
)
