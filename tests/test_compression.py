"""transfer/compression unit coverage: quantize/dequantize round trip and
error-feedback accumulation (previously exercised only via integration)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.transfer.compression import (
    compress,
    compress_with_error_feedback,
    dequantize_int8_blockwise,
    init_error_feedback,
    quantize_int8_blockwise,
)


@pytest.mark.parametrize("n,block", [(1024, 256), (1000, 256), (7, 4), (256, 256)])
def test_quantize_dequantize_error_bound(n, block):
    """Per-block symmetric int8: |x - deq(q)| <= blockwise absmax / 127
    (half-step rounding => <= scale/2, bounded by scale)."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=n), jnp.float32)
    q, scales = quantize_int8_blockwise(x, block)
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert scales.shape[0] == -(-n // block)
    y = dequantize_int8_blockwise(q, scales, block)[:n]
    err = np.abs(np.asarray(y) - np.asarray(x))
    xb = np.asarray(x)
    for b in range(scales.shape[0]):
        lo, hi = b * block, min((b + 1) * block, n)
        absmax = np.abs(xb[lo:hi]).max()
        # round() error is at most half a quantization step per block
        assert err[lo:hi].max() <= absmax / 127.0 * 0.5 + 1e-7


def test_quantize_preserves_shape_and_zero_blocks():
    x = jnp.zeros((3, 5, 7), jnp.float32)
    q, scales = quantize_int8_blockwise(x, block=16)
    assert q.shape == x.shape
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(scales) == 1.0)  # zero blocks use unit scale
    assert np.all(np.asarray(compress(x)) == 0.0)


def test_compress_round_trip_is_idempotent():
    """Quantizing an already-quantized tensor is exact: the grid points are
    fixed points of the transform."""
    x = jnp.asarray(np.random.default_rng(1).normal(size=512), jnp.float32)
    y1 = compress(x)
    y2 = compress(y1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0, atol=1e-6)


def test_error_feedback_single_step_identity():
    """One EF step: sent + residual == corrected gradient, exactly."""
    g = {"w": jnp.asarray(np.random.default_rng(2).normal(size=300), jnp.float32)}
    ef = init_error_feedback(g)
    assert np.all(np.asarray(ef["w"]) == 0.0)
    sent, ef2 = compress_with_error_feedback(g, ef)
    np.testing.assert_allclose(
        np.asarray(sent["w"]) + np.asarray(ef2["w"]), np.asarray(g["w"]),
        rtol=0, atol=1e-6,
    )


def test_error_feedback_accumulation_bounded():
    """The carried residual stays bounded by one quantization step — the
    error does NOT accumulate across steps (Karimireddy et al. 2019)."""
    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.normal(size=513) * 0.05, jnp.float32)}
    ef = init_error_feedback(g)
    step_bound = float(jnp.max(jnp.abs(g["w"]))) * 2.0 / 127.0 + 1e-6
    for i in range(25):
        sent, ef = compress_with_error_feedback(g, ef)
        # residual bounded by half a step of the corrected signal's scale;
        # corrected = g + e, |e| <= bound => stays a contraction
        assert float(jnp.max(jnp.abs(ef["w"]))) <= 2.0 * step_bound
    # and the pytree structure is preserved
    assert jax.tree.structure(sent) == jax.tree.structure(g)
    assert jax.tree.structure(ef) == jax.tree.structure(g)
