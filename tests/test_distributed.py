"""Multi-device behaviour, exercised in subprocesses so the main test
process keeps the real single-CPU device view (per the brief, XLA_FLAGS is
set only in dedicated entrypoints)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(script: str, timeout: int = 600) -> str:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout,
        cwd=REPO,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_ring_allreduce_matches_mean():
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.transfer.collective import ring_allreduce_tree
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:
            from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        def body(x):
            return ring_allreduce_tree({"g": x[0]}, "pod", [0, 2, 1, 3])["g"][None]
        h = shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                      check_rep=False)
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 33))
        got = np.asarray(jax.jit(h)(x))
        want = np.broadcast_to(np.mean(np.asarray(x), 0, keepdims=True), x.shape)
        assert np.allclose(got, want, atol=1e-5), np.abs(got-want).max()
        print("OK")
    """)


def test_sharded_train_step_matches_single_device():
    """The same train step on a (2,2) mesh and on 1 device produces the same
    loss and parameters — sharding is semantics-preserving."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch, reduced
        from repro.models import init_params
        from repro.models.model import abstract_params
        from repro.train import OptConfig, init_opt_state, make_train_step
        from repro.sharding.specs import (ShardingRules, set_mesh,
                                          make_param_shardings)
        import dataclasses

        cfg = reduced(get_arch("qwen2-7b"), vocab_size=256)
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        opt = init_opt_state(params)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0, 256),
                 "labels": jax.random.randint(key, (4, 32), 0, 256)}

        # single device reference
        rules0 = ShardingRules(batch=None, fsdp=None, tp=None)
        step0 = jax.jit(make_train_step(cfg, rules0, OptConfig()))
        p0, o0, m0 = step0(params, opt, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = ShardingRules(batch=("data",), fsdp="data", tp="model")
        set_mesh(mesh)
        pshard = make_param_shardings(mesh, rules, abstract_params(cfg))
        params_s = jax.device_put(params, pshard)
        opt_s = init_opt_state(params_s)
        with mesh:
            step1 = jax.jit(make_train_step(cfg, rules, OptConfig()))
            p1, o1, m1 = step1(params_s, opt_s, batch)
        # bf16 reduction order differs across shardings; semantics identical
        assert abs(float(m0["loss"]) - float(m1["loss"])) < 5e-3, (
            float(m0["loss"]), float(m1["loss"]))
        for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-3, rtol=1e-2)
        print("OK")
    """)


@pytest.mark.slow
def test_dryrun_single_cell_smoke():
    """One real dry-run cell end to end (multi-pod mesh, 512 devices)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "decode_32k", "--mesh", "multi", "--force",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=900,
        cwd=REPO, env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    art = json.loads(
        Path("/tmp/dryrun_test/smollm-135m__decode_32k__multi.json").read_text()
    )
    assert art["status"] == "ok"
    assert art["full"]["flops_per_device"] > 0
    assert art["mesh_shape"] == {"pod": 2, "data": 16, "model": 16}


def test_existing_dryrun_artifacts_complete():
    """The committed sweep must cover all 40 cells x 2 meshes with no
    errors (skips must carry a reason)."""
    art_dir = REPO / "artifacts" / "dryrun"
    if not art_dir.exists():
        pytest.skip("dry-run sweep not generated yet")
    files = list(art_dir.glob("*__*.json"))
    cells = [json.loads(f.read_text()) for f in files
             if f.name.count("__") == 2]
    assert len(cells) >= 80
    for a in cells:
        assert a["status"] in ("ok", "skipped"), (a["arch"], a["shape"], a["mesh"])
        if a["status"] == "skipped":
            assert a["skip_reason"]
        else:
            assert a["full"]["flops_per_device"] > 0
