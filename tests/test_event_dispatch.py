"""Runtime companion to skylint SKY004 (sim parity).

SKY004 statically proves that every event class in ``events.py`` has a
dispatch branch in BOTH event loops. This test proves the branches work:
each member of ``events.RATE_EVENTS``, plus ``VMFailure`` and a delayed
job arrival, is fed as a one-event stream through the vectorized simulator
and the oracle — both must consume it without raising and agree on the
outcome. A future event type added to one sim but not the other fails
SKY004 at lint time and this test at run time.
"""

import pytest

from repro.core import default_topology, direct_plan
from repro.transfer import TransferJob, simulate_multi, simulate_multi_reference
from repro.transfer.events import RATE_EVENTS, VMFailure

SRC, DST = "aws:us-west-2", "aws:eu-central-1"


@pytest.fixture(scope="module")
def top():
    return default_topology()


def _one_job(top, arrival_s=0.0):
    return [
        TransferJob(direct_plan(top, SRC, DST, 1.0, num_vms=2), "a",
                    arrival_s=arrival_s),
    ]


def _event_cases():
    cases = [
        pytest.param(
            lambda s, d: [cls(t_s=1.0, src=s, dst=d, factor=0.5)],
            id=cls.__name__,
        )
        for cls in RATE_EVENTS
    ]
    cases.append(pytest.param(
        lambda s, d: [VMFailure(t_s=1.0, job=0, region=s, count=1)],
        id="VMFailure",
    ))
    return cases


@pytest.mark.parametrize("make_faults", _event_cases())
def test_both_sims_consume_each_event_class(top, make_faults):
    faults = make_faults(top.index(SRC), top.index(DST))
    new = simulate_multi(_one_job(top), faults, seed=0)
    ref = simulate_multi_reference(_one_job(top), faults, seed=0)
    assert new.jobs[0].status == ref.jobs[0].status
    assert new.jobs[0].chunks_delivered == ref.jobs[0].chunks_delivered
    assert new.time_s == pytest.approx(ref.time_s, rel=1e-9)


def test_both_sims_consume_delayed_arrival(top):
    """Arrivals dispatch as plain ints in both event loops (the SKY004
    ``int`` branch): a job arriving mid-simulation must start identically
    on both sides."""
    new = simulate_multi(_one_job(top, arrival_s=1.5), [], seed=0)
    ref = simulate_multi_reference(_one_job(top, arrival_s=1.5), [], seed=0)
    assert new.jobs[0].status == ref.jobs[0].status == "done"
    assert new.jobs[0].chunks_delivered == ref.jobs[0].chunks_delivered
    assert new.time_s == pytest.approx(ref.time_s, rel=1e-9)
    assert new.time_s > 1.5  # the arrival actually gated the start


def test_rate_events_is_the_full_rate_family():
    """RATE_EVENTS members all carry the (t_s, src, dst, factor) shape the
    shared rate-multiplication handler expects."""
    for cls in RATE_EVENTS:
        ev = cls(t_s=0.0, src=0, dst=1, factor=0.5)
        assert (ev.t_s, ev.src, ev.dst, ev.factor) == (0.0, 0, 1, 0.5)
