"""Fast-mode smoke over every example (ISSUE 4 satellite).

Examples are executable documentation; nothing else imports them, so
without this sweep they rot silently when an API they demonstrate moves.
Each one must run to completion (exit 0, its own internal asserts intact)
under ``REPRO_BENCH_FAST=1`` — the same abbreviation switch the benchmark
suite uses — which the longer examples honor by shrinking volumes/steps.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
EXAMPLES = sorted((ROOT / "examples").glob("*.py"))


def test_every_example_is_covered():
    # paranoia: the glob must actually see the examples directory
    names = {p.stem for p in EXAMPLES}
    assert {"quickstart", "adaptive_transfer",
            "fault_tolerant_transfer"} <= names


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.stem)
def test_example_runs_in_fast_mode(path, tmp_path):
    env = dict(
        os.environ,
        REPRO_BENCH_FAST="1",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=str(ROOT / "src"),
    )
    res = subprocess.run(
        [sys.executable, str(path)],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=tmp_path,  # artifacts land in a scratch dir, not the repo
    )
    assert res.returncode == 0, (
        f"{path.name} failed\n--- stdout ---\n{res.stdout[-3000:]}\n"
        f"--- stderr ---\n{res.stderr[-3000:]}"
    )
