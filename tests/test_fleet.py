"""Fleet control plane (ISSUE 7): multi-tenant service on one belief.

Covers the policy layer the fleet adds over the calibrated loop —
weighted max-min sharing, admission control (deferral, headroom boost,
deadline carve-out), per-tenant VM quotas with idle-pool borrowing, the
rotating probe focus, cross-tenant probe dedup, batched cohort
admission, and the report protocol — without re-testing the inherited
execution machinery.
"""

import numpy as np
import pytest

from repro.calibrate import (
    CalibratedTransferService,
    Calibrator,
    DriftModel,
)
from repro.core import PlanSpec, Planner, default_topology, milp
from repro.transfer import (
    FleetController,
    FleetReport,
    TenantReport,
    TenantSpec,
    TransferRequest,
)
from repro.transfer.fleet import weighted_max_min

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "azure:canadacentral"

SVC_KW = dict(backend="jax", max_relays=6, check_interval_s=8.0,
              max_segments=40)


def _drift(seed=0):
    return DriftModel(default_topology(), seed=seed, drift_sigma=0.0,
                      diurnal_amp=0.0)


def _fleet(tenants, **kw):
    merged = {**SVC_KW, **kw}
    return FleetController(_drift(), tenants=tenants, **merged)


# ------------------------------------------------------- weighted max-min
def test_weighted_max_min_satisfies_small_demands():
    # demand 1 fits under its fair share; the excess waterfalls onward
    alloc = weighted_max_min([1.0, 1.0], [1.0, 10.0], 6.0)
    assert alloc == [1.0, 5.0]


def test_weighted_max_min_respects_weights():
    alloc = weighted_max_min([1.0, 3.0], [10.0, 10.0], 8.0)
    assert alloc == pytest.approx([2.0, 6.0])


def test_weighted_max_min_conserves_capacity():
    alloc = weighted_max_min([2.0, 1.0, 1.0], [5.0, 5.0, 5.0], 8.0)
    assert sum(alloc) == pytest.approx(8.0)
    assert all(a <= 5.0 + 1e-9 for a in alloc)


def test_weighted_max_min_zero_demand_gets_nothing():
    assert weighted_max_min([1.0, 1.0], [0.0, 4.0], 10.0) == [0.0, 4.0]


# ------------------------------------------------------------- validation
def test_tenant_spec_rejects_bad_slo_class():
    with pytest.raises(ValueError, match="slo_class"):
        TenantSpec("t", slo_class="best-effort")


def test_tenant_spec_rejects_nonpositive_weight():
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("t", weight=0.0)


def test_fleet_needs_tenants():
    with pytest.raises(ValueError, match="TenantSpec"):
        FleetController(_drift(), tenants=[], **SVC_KW)


def test_fleet_rejects_duplicate_tenants():
    with pytest.raises(ValueError, match="duplicate"):
        FleetController(
            _drift(), tenants=[TenantSpec("a"), TenantSpec("a")], **SVC_KW
        )


def test_submit_validation():
    fleet = _fleet([TenantSpec("a"), TenantSpec("b")])
    with pytest.raises(ValueError, match="tenant"):
        fleet.submit(TransferRequest("j0", SRC, DST, 1.0, 1.0))
    with pytest.raises(KeyError, match="unknown tenant"):
        fleet.submit(TransferRequest("j0", SRC, DST, 1.0, 1.0), tenant="c")
    fleet.submit(TransferRequest("j0", SRC, DST, 1.0, 1.0), tenant="a")
    with pytest.raises(ValueError, match="duplicate job"):
        fleet.submit(TransferRequest("j0", SRC, DST, 1.0, 1.0), tenant="b")


def test_single_tenant_submit_defaults():
    fleet = _fleet([TenantSpec("only")])
    fleet.submit(TransferRequest("j0", SRC, DST, 1.0, 1.0))
    assert fleet._tenant_of["j0"] == "only"


# -------------------------------------------------------------- admission
def test_headroom_boost_grants_idle_margin():
    """An uncontended wave is work-conserving: admitted goals rise above
    the request, up to ``headroom_boost`` x."""
    fleet = _fleet([TenantSpec("a")], headroom_boost=1.5)
    req = fleet.submit(TransferRequest("j0", SRC, DST, 1.0, 1.0))
    states = fleet._admit_queue()
    assert states[0].status == "planned"
    assert req.tput_goal_gbps == pytest.approx(1.5)


def test_admission_defers_squeezed_bulk_job():
    """A bulk job squeezed below ``min_admit_frac`` of its request is
    deferred — arrival pushed past the queue ahead, full goal kept."""
    fleet = _fleet([TenantSpec("a")], admission_margin=0.05,
                   min_admit_frac=0.9, headroom_boost=1.0)
    fleet.submit(TransferRequest("j0", SRC, DST, 40.0, 4.0))
    fleet.submit(TransferRequest("j1", SRC, DST, 40.0, 4.0))
    fleet._admit_queue()
    assert "j1" in fleet._deferred
    assert fleet._deferred["j1"] > 0.0


def test_deadline_jobs_admitted_before_bulk():
    """With the route saturated by a bulk tenant, the deadline tenant is
    still admitted at (at least) its min-frac goal, never deferred."""
    fleet = _fleet(
        [TenantSpec("bulk"), TenantSpec("dl", slo_class="deadline")],
        admission_margin=0.3, headroom_boost=1.0,
    )
    fleet.submit(TransferRequest("b0", SRC, DST, 40.0, 6.0), tenant="bulk")
    fleet.submit(
        TransferRequest("d0", SRC, DST, 4.0, 6.0, deadline_s=300.0),
        tenant="dl",
    )
    goals = fleet._admission(list(fleet._queue))
    assert "d0" not in fleet._deferred
    assert goals["d0"] >= fleet.min_admit_frac * 6.0 - 1e-9


def test_fair_shares_carve_deadline_first():
    """On a contended link the deadline tenant's share is carved out at
    its full demand before bulk tenants water-fill the residual."""
    fleet = _fleet(
        [TenantSpec("bulk"), TenantSpec("dl", slo_class="deadline")],
        headroom_boost=1.0,
    )
    r_bulk = TransferRequest("b0", SRC, DST, 10.0, 8.0)
    r_dl = TransferRequest("d0", SRC, DST, 10.0, 8.0, deadline_s=300.0)
    fleet.submit(r_bulk, tenant="bulk")
    fleet.submit(r_dl, tenant="dl")
    reqs = list(fleet._queue)
    shares = fleet._fair_shares(reqs, {"b0": 8.0, "d0": 8.0})
    contended = np.isfinite(shares["dl"]) & np.isfinite(shares["bulk"])
    assert contended.any(), "16 Gbps on one route must contend somewhere"
    assert (shares["dl"][contended] >= shares["bulk"][contended] - 1e-9).all()


# ---------------------------------------------------------- VM quotas
def test_vm_budget_clamps_isolated_service():
    """A service-level ``vm_budget`` backs the goal off until the plan
    fits the subscription — and records the clamp."""
    free = CalibratedTransferService(_drift(), **SVC_KW)
    free.submit(TransferRequest("j0", SRC, DST, 8.0, 6.0))
    vms_free = free._admit_queue()[0].plan.num_vms
    assert vms_free > 2

    capped = CalibratedTransferService(_drift(), vm_budget=2, **SVC_KW)
    capped.submit(TransferRequest("j0", SRC, DST, 8.0, 6.0))
    st = capped._admit_queue()[0]
    assert st.plan.num_vms <= 2
    assert "j0" in capped._vm_clamped


def test_fleet_quota_borrowing_uses_idle_pool():
    """At admission a tenant is held to its own quota; once another
    tenant's jobs drain, a re-plan may provision from the pooled idle
    quota — and the borrow is counted on the tenant report."""
    fleet = _fleet(
        [TenantSpec("a", vm_quota=2), TenantSpec("b", vm_quota=4)],
        headroom_boost=1.0,
    )
    fleet.submit(TransferRequest("a0", SRC, DST, 4.0, 6.0), tenant="a")
    fleet.submit(TransferRequest("b0", SRC2, DST, 4.0, 2.0), tenant="b")
    states = fleet._admit_queue()
    assert fleet._vm_budget_for(states[0].req) == 2.0  # b0 still active
    # b's job drains -> its quota is idle -> a may borrow up to the pool
    for st in states:
        if st.req.name == "b0":
            st.remaining_chunks = 0
    assert fleet._vm_budget_for(states[0].req) == pytest.approx(6.0)
    assert fleet._quota_borrows.get("a", 0) >= 1


def test_fleet_quota_enforced_at_admission():
    fleet = _fleet([TenantSpec("a", vm_quota=2)], headroom_boost=1.0)
    fleet.submit(TransferRequest("a0", SRC, DST, 8.0, 6.0), tenant="a")
    st = fleet._admit_queue()[0]
    assert st.plan.num_vms <= 2
    assert "a0" in fleet._quota_clamped


# ------------------------------------------------------------ probe focus
def test_probe_focus_rotates_tenants():
    fleet = _fleet([TenantSpec("a"), TenantSpec("b")], headroom_boost=1.0)
    fleet.submit(TransferRequest("a0", SRC, DST, 2.0, 1.0), tenant="a")
    fleet.submit(TransferRequest("b0", SRC2, DST, 2.0, 1.0), tenant="b")
    states = fleet._admit_queue()
    act = list(range(len(states)))
    first, _ = fleet._probe_focus(states, act)
    second, _ = fleet._probe_focus(states, act)
    third, _ = fleet._probe_focus(states, act)
    assert first != second, "consecutive rounds focus different tenants"
    assert third == first, "two tenants -> period-2 rotation"
    assert all(len(c) == 1 for c in (first, second, third))


def test_probe_dedup_skips_fresh_links():
    """A broad sweep skips links probed inside the dedup window — the
    fleet's cross-tenant amortization — while targeted rounds always run."""
    top = default_topology()
    drift = _drift()
    planner = Planner(top, max_relays=6)
    from repro.calibrate import BeliefGrid

    from repro.calibrate import BeliefGrid as _BG, ProbeBudget

    # a budget wide enough to cover the whole candidate subgraph: the
    # second sweep then has no fresh links left and must dedup (a narrow
    # budget would just pick the next-best unprobed candidates instead)
    n_cand = len(Calibrator(_BG(top)).candidate_links(
        planner, [(SRC, DST)]))
    cal = Calibrator(
        BeliefGrid(top), dedup_window_s=60.0,
        budget=ProbeBudget(usd_per_round=1e9, seconds_per_round=30.0,
                           max_probes_per_round=n_cand),
    )
    truth = drift.tput_at(0.0)
    r0 = cal.run_round(0.0, truth, planner=planner,
                       contexts=[(SRC, DST)])
    assert r0.n_probes > 0 and r0.deduped == 0
    r1 = cal.run_round(1.0, truth, planner=planner,
                       contexts=[(SRC, DST)])
    assert r1.n_probes == 0
    assert r1.deduped >= r0.n_probes  # everything fresh is skipped
    link = (r0.records[0].src, r0.records[0].dst)
    r2 = cal.run_round(2.0, truth, links=[link])  # targeted: no dedup
    assert r2.n_probes == 1 and r2.deduped == 0


# ------------------------------------------------------- cohort admission
def test_cohort_admission_matches_sequential_plans():
    """``plan_cohort`` (the batched admission sweep) returns plans
    equivalent to the sequential ``plan()`` path, in spec order."""
    planner = Planner(default_topology(), max_relays=6)
    specs = [
        PlanSpec(objective="cost_min", src=SRC, dst=DST,
                 tput_goal_gbps=g, volume_gb=2.0, backend="jax")
        for g in (1.0, 2.0, 3.0)
    ]
    batched = planner.plan_cohort(specs)
    for sp, plan in zip(specs, batched):
        solo = planner.plan(sp)
        assert plan.solver_status == solo.solver_status == "optimal"
        assert plan.throughput == pytest.approx(solo.throughput)
        assert plan.total_cost == pytest.approx(solo.total_cost, rel=1e-6)


def test_cohort_admission_reuses_route_structure():
    fleet = _fleet([TenantSpec("a")], headroom_boost=1.0)
    for i in range(3):
        fleet.submit(TransferRequest(f"j{i}", SRC, DST, 2.0, 1.0),
                     tenant="a")
    b0 = milp.N_STRUCT_BUILDS
    states = fleet._admit_queue()
    assert all(s.status == "planned" for s in states)
    assert milp.N_STRUCT_BUILDS - b0 <= 1  # one route, one first touch


# ------------------------------------------------------------ end to end
def test_fleet_run_end_to_end():
    """Two tenants, drift-free world: everything delivers, no re-plan
    re-assembles an LP structure, and the report speaks the protocol."""
    fleet = _fleet(
        [TenantSpec("a", vm_quota=8),
         TenantSpec("dl", weight=2.0, slo_class="deadline")],
    )
    fleet.submit(TransferRequest("a0", SRC, DST, 2.0, 2.0, chunk_mb=4.0),
                 tenant="a")
    fleet.submit(
        TransferRequest("d0", SRC2, DST, 2.0, 2.0, chunk_mb=4.0,
                        deadline_s=120.0),
        tenant="dl",
    )
    rep = fleet.run()
    assert isinstance(rep, FleetReport)
    assert sum(j.delivered_gb for j in rep.jobs) == pytest.approx(4.0)
    assert sum(r.structure_builds for j in rep.jobs
               for r in j.replans) == 0

    d = rep.to_dict()
    assert d["kind"] == "fleet"
    assert d["tenants_n"] == 2
    assert {t["kind"] for t in d["tenants"]} == {"tenant"}
    assert {t["name"] for t in d["tenants"]} == {"a", "dl"}
    dl = next(t for t in rep.tenants if t.name == "dl")
    assert isinstance(dl, TenantReport)
    assert dl.deadline_misses == 0
    assert "[fleet]" in rep.summary()
    assert "[tenant]" in dl.summary()
