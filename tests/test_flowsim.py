"""Fluid data-plane simulator invariants + paper §6 behaviours."""

import pytest

from repro.core import Planner, default_topology, direct_plan
from repro.transfer import (
    execute_plan,
    simulate_transfer,
    simulate_transfer_reference,
)

SRC, DST = "aws:us-west-2", "aws:eu-central-1"


@pytest.fixture(scope="module")
def top():
    return default_topology()


def test_delivers_every_chunk(top):
    plan = direct_plan(top, SRC, DST, 4.0, num_vms=2)
    res = simulate_transfer(plan, chunk_mb=16, seed=0)
    import math

    expect = math.ceil(4.0 * 8 / (16 * 8 / 1024))
    assert res.chunks_delivered == expect


def test_no_straggler_sim_close_to_plan(top):
    plan = direct_plan(top, SRC, DST, 8.0, num_vms=2)
    res = simulate_transfer(plan, straggler_prob=0.0, chunk_mb=16, seed=0)
    assert res.tput_gbps >= plan.throughput * 0.7
    assert res.tput_gbps <= plan.throughput * 1.05


def test_dynamic_dispatch_beats_static_under_stragglers(top):
    """Paper §6: dynamic chunk dispatch vs GridFTP round-robin."""
    plan = direct_plan(top, SRC, DST, 4.0, num_vms=2)
    dyn = simulate_transfer(plan, dispatch="dynamic", seed=3, chunk_mb=16)
    sta = simulate_transfer(plan, dispatch="static", seed=3, chunk_mb=16)
    assert dyn.tput_gbps > sta.tput_gbps


def test_realized_cost_close_to_planned(top):
    plan = direct_plan(top, SRC, DST, 8.0, num_vms=2)
    rep = execute_plan(plan, seed=0, chunk_mb=16)
    assert rep.cost_ratio == pytest.approx(1.0, abs=0.35)
    # egress accounting: all bytes billed at the grid price
    assert rep.sim.egress_cost > 0 and rep.sim.vm_cost > 0


def test_overlay_sim_beats_direct_sim():
    import dataclasses

    # 4-VM budget keeps the connection count proportionate to the 16 GB /
    # 16 MB chunk stream, so both plans reach steady state in simulation.
    top = dataclasses.replace(default_topology(), limit_vm=4)
    src, dst = "azure:canadacentral", "gcp:asia-northeast1"
    dp = direct_plan(top, src, dst, 16.0, num_vms=4)
    planner = Planner(top)
    op = planner.plan_tput_max(src, dst, dp.cost_per_gb * 1.3, 16.0, n_samples=8)
    assert op.throughput > dp.throughput * 1.5  # planner-level speedup
    sim_d = simulate_transfer(dp, seed=1, chunk_mb=16)
    sim_o = simulate_transfer(op, seed=1, chunk_mb=16)
    assert sim_o.tput_gbps > sim_d.tput_gbps * 1.3  # survives the data plane


@pytest.mark.parametrize("dispatch,seed,volume,chunk_mb", [
    ("dynamic", 0, 4.0, 16), ("dynamic", 3, 4.0, 16), ("static", 0, 4.0, 16),
    # fewer chunks than first-hop connections: static conns without an
    # assignment must starve, not steal from the shared ready queue
    ("static", 0, 0.5, 256),
    ("dynamic", 0, 0.5, 256),
])
def test_vectorized_sim_matches_reference(top, dispatch, seed, volume, chunk_mb):
    """The array-based event loop reproduces the object-per-connection
    reference: identical delivered-chunk counts at fixed seed, throughput
    within scheduler-tie noise."""
    plan = direct_plan(top, SRC, DST, volume, num_vms=2)
    new = simulate_transfer(plan, chunk_mb=chunk_mb, seed=seed,
                            dispatch=dispatch)
    ref = simulate_transfer_reference(
        plan, chunk_mb=chunk_mb, seed=seed, dispatch=dispatch
    )
    assert new.chunks_delivered == ref.chunks_delivered
    assert new.tput_gbps == pytest.approx(ref.tput_gbps, rel=0.1)
    assert new.total_cost == pytest.approx(ref.total_cost, rel=0.1)


def test_vectorized_sim_matches_reference_on_overlay():
    import dataclasses

    top = dataclasses.replace(default_topology(), limit_vm=4)
    src, dst = "azure:canadacentral", "gcp:asia-northeast1"
    planner = Planner(top)
    dp = direct_plan(top, src, dst, 16.0, num_vms=4)
    op = planner.plan_tput_max(src, dst, dp.cost_per_gb * 1.3, 16.0, n_samples=8)
    new = simulate_transfer(op, seed=1, chunk_mb=16)
    ref = simulate_transfer_reference(op, seed=1, chunk_mb=16)
    assert new.chunks_delivered == ref.chunks_delivered
    assert new.tput_gbps == pytest.approx(ref.tput_gbps, rel=0.15)


def test_utilization_and_bottlenecks_reported(top):
    plan = direct_plan(top, SRC, DST, 4.0, num_vms=1)
    res = simulate_transfer(plan, seed=0, chunk_mb=16)
    assert set(res.utilization) >= {"source_vm", "dest_vm", "source_link"}
    assert all(0.0 <= u <= 1.2 for u in res.utilization.values())
