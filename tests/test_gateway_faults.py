"""Gateway fault tolerance: chunk retry, checksummed resume, DirStore."""

import random
import threading

import numpy as np
import pytest

from repro.core import Planner, toy_topology
from repro.transfer import (
    BlobStore,
    DirStore,
    FaultInjector,
    transfer_objects,
)
from repro.transfer.chunk import chunk_manifest
from repro.transfer.gateway import _retry_delay


@pytest.fixture(scope="module")
def toy_plan():
    top = toy_topology(n=5, seed=2)
    return Planner(top, max_relays=3).plan_cost_min("toy:r0", "toy:r1", 2.0, 0.01)


def _stores(n_objects=4, size=1_200_000):
    rng = np.random.default_rng(0)
    src = BlobStore()
    keys = []
    for i in range(n_objects):
        k = f"shard/{i:03d}.npy"
        src.put(k, rng.bytes(size + i * 31337))
        keys.append(k)
    return src, keys


def test_gateway_kill_mid_transfer_zero_data_loss(toy_plan):
    """Acceptance: a gateway kill mid-transfer completes with
    checksum_failures == 0, nothing missing, and no chunk delivered twice
    (duplicates are discarded, not re-committed)."""
    src, keys = _stores()
    dst = BlobStore()
    inj = FaultInjector(kill_worker_after={(0, 0): 2})
    rep = transfer_objects(
        toy_plan, src, dst, keys, chunk_bytes=1 << 18,
        fault_injector=inj, workers_per_hop=3,
    )
    assert rep.faults_injected >= 1
    assert rep.retried_chunks >= 1  # the carried chunk was re-dispatched
    assert rep.checksum_failures == 0
    assert rep.chunks_missing == 0
    for k in keys:
        assert dst.get(k) == src.get(k)  # byte-identical: zero data loss


def test_gateway_corruption_detected_and_retried(toy_plan):
    src, keys = _stores(n_objects=2)
    dst = BlobStore()
    _, chunk_sums, _ = chunk_manifest(src, keys, 1 << 18)
    victims = sorted(chunk_sums)[:3]
    inj = FaultInjector(corrupt_chunks=victims)
    rep = transfer_objects(
        toy_plan, src, dst, keys, chunk_bytes=1 << 18, fault_injector=inj
    )
    assert rep.faults_injected == len(victims)
    assert rep.retried_chunks >= len(victims)
    assert rep.checksum_failures == 0 and rep.chunks_missing == 0
    for k in keys:
        assert dst.get(k) == src.get(k)


def test_gateway_resume_skips_verified_objects(toy_plan):
    """Checksummed resume: objects the destination already holds verified
    are never re-sent; a corrupted destination copy is re-transferred."""
    src, keys = _stores(n_objects=3)
    dst = BlobStore()
    rep1 = transfer_objects(toy_plan, src, dst, keys, chunk_bytes=1 << 18)
    assert rep1.objects_skipped == 0 and rep1.bytes_moved > 0
    # second run: everything verified at the destination, zero bytes move
    rep2 = transfer_objects(toy_plan, src, dst, keys, chunk_bytes=1 << 18)
    assert rep2.objects_skipped == len(keys)
    assert rep2.chunks == 0 and rep2.bytes_moved == 0
    # mangle one destination object: only that one is re-transferred
    blob = bytearray(dst.get(keys[0]))
    blob[0] ^= 0xFF
    dst.put(keys[0], bytes(blob))
    rep3 = transfer_objects(toy_plan, src, dst, keys, chunk_bytes=1 << 18)
    assert rep3.objects_skipped == len(keys) - 1
    assert rep3.chunks > 0
    assert dst.get(keys[0]) == src.get(keys[0])


def test_zero_byte_objects_are_committed(toy_plan):
    src, dst = BlobStore(), BlobStore()
    src.put("empty.bin", b"")
    src.put("tiny.bin", b"x" * 17)
    rep = transfer_objects(toy_plan, src, dst, ["empty.bin", "tiny.bin"])
    assert rep.checksum_failures == 0 and rep.chunks_missing == 0
    assert dst.exists("empty.bin") and dst.get("empty.bin") == b""
    assert dst.get("tiny.bin") == src.get("tiny.bin")


def test_dirstore_directory_is_authoritative(tmp_path):
    """DirStore keeps no in-memory payload copy: reads come from disk, and
    externally-written files are visible immediately."""
    store = DirStore(tmp_path)
    store.put("a/b.bin", b"\x01" * 1024)
    assert not hasattr(store, "_data")  # no inherited dict doubling memory
    assert store.get("a/b.bin") == b"\x01" * 1024
    assert store.get_range("a/b.bin", 10, 5) == b"\x01" * 5
    # the directory is the source of truth: out-of-band writes are served
    (tmp_path / "ext__obj.bin").write_bytes(b"xyz")
    assert store.exists("ext/obj.bin")
    assert store.get("ext/obj.bin") == b"xyz"
    assert sorted(store.keys()) == ["a/b.bin", "ext/obj.bin"]
    assert store.size("ext/obj.bin") == 3


def test_dirstore_tmp_suffix_does_not_collide(tmp_path):
    """Keys whose names differ only by extension must not share a tmp path
    (the old with_suffix() scheme clobbered 'x.npy' with 'x.txt')."""
    store = DirStore(tmp_path)
    store.put("x.npy", b"npy")
    store.put("x.txt", b"txt")
    assert store.get("x.npy") == b"npy"
    assert store.get("x.txt") == b"txt"
    assert sorted(store.keys()) == ["x.npy", "x.txt"]


def test_gateway_through_dirstore_roundtrip(toy_plan, tmp_path):
    src, keys = _stores(n_objects=2, size=400_000)
    dst = DirStore(tmp_path / "dst")
    rep = transfer_objects(toy_plan, src, dst, keys, chunk_bytes=1 << 17)
    assert rep.checksum_failures == 0 and rep.chunks_missing == 0
    for k in keys:
        assert dst.get(k) == src.get(k)


def test_retry_delay_backoff_shape_and_determinism():
    """Exponential, capped, jittered in [0.5, 1.5), seeded: the same seed
    replays the same delays, attempt 0 (first dispatch) never waits."""
    assert _retry_delay(0, 0.01, 0.25, random.Random(1)) == 0.0
    assert _retry_delay(3, 0.0, 0.25, random.Random(1)) == 0.0
    rng = random.Random(7)
    seen = [_retry_delay(a, 0.01, 0.25, rng) for a in range(1, 12)]
    for a, d in enumerate(seen, start=1):
        nominal = min(0.01 * 2.0 ** (a - 1), 0.25)
        assert 0.5 * nominal <= d < 1.5 * nominal
    assert max(seen) < 1.5 * 0.25  # the cap really binds deep attempts
    replay = random.Random(7)
    assert seen == [
        _retry_delay(a, 0.01, 0.25, replay) for a in range(1, 12)
    ]


class _OneHangStore(BlobStore):
    """Serves normally except the FIRST get_range call, which blocks until
    released — a hung disk/network read holding its worker thread hostage."""

    def __init__(self):
        super().__init__()
        self._lock = threading.Lock()
        self._hung = False
        self.release = threading.Event()

    def get_range(self, key, offset, length):
        # manifest checksumming reads from the main thread: only a gateway
        # worker's read may hang, and only the first one
        if threading.current_thread() is not threading.main_thread():
            with self._lock:
                hang, self._hung = not self._hung, True
            if hang:
                self.release.wait()
        return super().get_range(key, offset, length)


def test_gateway_counts_leaked_workers_and_still_delivers(toy_plan):
    """Satellite: a worker stuck in a store call survives the bounded
    shutdown join — the report counts it, the registered
    ``gateway.workers_leaked`` counter records it (the RuntimeWarning it
    replaced was one-shot per process), and stall re-dispatch still
    lands every byte."""
    from repro.obs.metrics import get_registry

    leaked0 = get_registry().counter("gateway.workers_leaked").value
    rng = np.random.default_rng(3)
    src = _OneHangStore()
    keys = []
    for i in range(3):
        k = f"shard/{i:03d}.npy"
        src.put(k, rng.bytes(600_000))
        keys.append(k)
    dst = BlobStore()
    try:
        rep = transfer_objects(
            toy_plan, src, dst, keys, chunk_bytes=1 << 17,
            workers_per_hop=3, stall_timeout_s=0.2,
        )
    finally:
        src.release.set()  # let the hostage thread exit after the test
    assert rep.workers_leaked >= 1
    counted = get_registry().counter("gateway.workers_leaked").value
    assert counted - leaked0 == rep.workers_leaked
    assert rep.to_dict()["metrics"]["gateway.workers_leaked"] == counted
    assert rep.chunks_missing == 0 and rep.checksum_failures == 0
    for k in keys:
        assert dst.get(k) == src.get(k)  # zero loss despite the leak
