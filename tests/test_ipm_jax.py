"""Batched JAX IPM (solver/ipm_jax) vs the numpy reference solver."""

import numpy as np
import pytest

from repro.core import Planner, default_topology, toy_topology
from repro.core import milp
from repro.core.solver.ipm import solve_lp
from repro.core.solver.ipm_jax import solve_lp_batched


def test_batched_matches_reference_on_skyplane_lps():
    top = toy_topology(n=6, seed=4)
    lp = milp.build_lp(top, 0, 1, 1.0)
    goals = np.array([0.5, 1.5, 2.5, 3.5])
    b_batch = np.tile(lp.b_ub[None, :], (len(goals), 1))
    b_batch[:, lp.row_4c] = -goals
    b_batch[:, lp.row_4d] = -goals
    xs, funs, ok = solve_lp_batched(lp.c, lp.A_ub, b_batch, lp.A_eq, lp.b_eq)
    for i, g in enumerate(goals):
        lp_i = milp.build_lp(top, 0, 1, float(g))
        ref = solve_lp(lp_i.c, lp_i.A_ub, lp_i.b_ub, lp_i.A_eq, lp_i.b_eq)
        assert ok[i] == ref.ok
        if ref.ok:
            assert funs[i] == pytest.approx(ref.fun, rel=1e-5, abs=1e-8)


def test_fast_frontier_close_to_integerized():
    top = default_topology()
    planner = Planner(top)
    src, dst = "aws:us-east-1", "gcp:europe-west4"
    fast = planner.pareto_frontier_fast(src, dst, 10.0, n_samples=16)
    exact = planner.pareto_frontier(src, dst, 10.0, n_samples=4)
    assert len(fast) >= 12
    for p in exact:
        near = min(fast, key=lambda q: abs(q.tput_goal - p.tput_goal))
        assert near.cost_per_gb == pytest.approx(p.cost_per_gb, rel=0.05)
    # frontier plans are feasible (continuous relaxation: no integrality)
    for q in fast[:: max(len(fast) // 4, 1)]:
        assert q.plan.validate() == []
