"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the brief's per-kernel allclose requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback sweeps instead
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_bhsd_ref
from repro.kernels.quantize.ops import dequantize_int8, quantize_int8
from repro.kernels.quantize.quantize import quantize_int8_2d
from repro.kernels.quantize.ref import quantize_int8_2d_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_bhsp
from repro.kernels.ssd_scan.ref import ssd_scan_bhsp_ref


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kv,d,block",
    [
        (1, 128, 2, 2, 32, 64),   # MHA
        (2, 256, 4, 2, 64, 128),  # GQA 2:1
        (1, 192, 6, 2, 16, 64),   # seq not a multiple of the block (pad path)
        (1, 128, 8, 1, 32, 64),   # MQA
    ],
)
def test_flash_attention_sweep(dtype, b, s, h, kv, d, block):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=block, block_k=block)
    ref = attention_bhsd_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        q_per_kv=h // kv, causal=True, scale=d ** -0.5,
    )
    ref = jnp.moveaxis(ref, 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


@pytest.mark.parametrize("window", [32, 64, 200])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, h, kv, d = 1, 256, 2, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    ref = attention_bhsd_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        q_per_kv=1, causal=True, window=window, scale=d ** -0.5,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.moveaxis(ref, 1, 2)), atol=2e-5
    )


# ------------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,s,p,n,q",
    [(1, 2, 64, 16, 16, 16), (2, 3, 128, 16, 32, 32), (1, 4, 256, 32, 64, 64)],
)
def test_ssd_scan_sweep(dtype, b, h, s, p, n, q):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, h, s, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), dtype)
    cm = jax.random.normal(ks[4], (b, s, n), dtype)
    yk, sk = ssd_scan_bhsp(x, dt, a, bm, cm, chunk=q, interpret=True)
    yr, sr = ssd_scan_bhsp_ref(x, dt, a, bm, cm, chunk=q)
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=tol, rtol=tol)


def test_ssd_state_continuity():
    """Final state from the kernel == running the recurrence token by token."""
    b, h, s, p, n, q = 1, 1, 64, 8, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, h, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    _, s_kernel = ssd_scan_bhsp(x, dt, a, bm, cm, chunk=q, interpret=True)
    state = np.zeros((p, n))
    for t in range(s):
        da = float(dt[0, 0, t]) * float(a[0])
        state = state * np.exp(da) + float(dt[0, 0, t]) * np.outer(
            np.asarray(x[0, 0, t]), np.asarray(bm[0, t])
        )
    np.testing.assert_allclose(np.asarray(s_kernel[0, 0]), state, atol=1e-3)


# ------------------------------------------------------------------- quantize
@given(
    n=st.integers(1, 4000),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 100),
)
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale, seed):
    """|x - dq(q(x))| <= absmax/127/2 + eps per block, any shape."""
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale, np.float32
    )
    q, s = quantize_int8(jnp.asarray(x))
    xr = np.asarray(dequantize_int8(q, s))
    bound = np.abs(x).max() / 127.0 * 0.5001 + 1e-6
    assert np.abs(xr - x).max() <= bound * 1.01 + 1e-6


@pytest.mark.parametrize("rows,block", [(8, 256), (16, 128), (8, 512)])
def test_quantize_kernel_matches_ref(rows, block):
    x = jax.random.normal(jax.random.PRNGKey(4), (rows * 4, block)) * 10
    qk, sk = quantize_int8_2d(x, block=block, rows=rows, interpret=True)
    qr, sr = quantize_int8_2d_ref(x)
    assert np.array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_quantize_zero_block():
    x = jnp.zeros((8, 256))
    q, s = quantize_int8_2d(x, interpret=True)
    assert np.all(np.asarray(q) == 0)
    xr = dequantize_int8(q.reshape(-1), s[:, 0])
    assert np.all(np.asarray(xr) == 0)
