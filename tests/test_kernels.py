"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the brief's per-kernel allclose requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback sweeps instead
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_bhsd_ref
from repro.kernels.quantize.ops import dequantize_int8, quantize_int8
from repro.kernels.quantize.quantize import quantize_int8_2d
from repro.kernels.quantize.ref import quantize_int8_2d_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_bhsp
from repro.kernels.ssd_scan.ref import ssd_scan_bhsp_ref


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kv,d,block",
    [
        (1, 128, 2, 2, 32, 64),   # MHA
        (2, 256, 4, 2, 64, 128),  # GQA 2:1
        (1, 192, 6, 2, 16, 64),   # seq not a multiple of the block (pad path)
        (1, 128, 8, 1, 32, 64),   # MQA
    ],
)
def test_flash_attention_sweep(dtype, b, s, h, kv, d, block):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=block, block_k=block)
    ref = attention_bhsd_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        q_per_kv=h // kv, causal=True, scale=d ** -0.5,
    )
    ref = jnp.moveaxis(ref, 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


@pytest.mark.parametrize("window", [32, 64, 200])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, h, kv, d = 1, 256, 2, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    ref = attention_bhsd_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        q_per_kv=1, causal=True, window=window, scale=d ** -0.5,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.moveaxis(ref, 1, 2)), atol=2e-5
    )


# ------------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,s,p,n,q",
    [(1, 2, 64, 16, 16, 16), (2, 3, 128, 16, 32, 32), (1, 4, 256, 32, 64, 64)],
)
def test_ssd_scan_sweep(dtype, b, h, s, p, n, q):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, h, s, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), dtype)
    cm = jax.random.normal(ks[4], (b, s, n), dtype)
    yk, sk = ssd_scan_bhsp(x, dt, a, bm, cm, chunk=q, interpret=True)
    yr, sr = ssd_scan_bhsp_ref(x, dt, a, bm, cm, chunk=q)
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=tol, rtol=tol)


def test_ssd_state_continuity():
    """Final state from the kernel == running the recurrence token by token."""
    b, h, s, p, n, q = 1, 1, 64, 8, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, h, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    _, s_kernel = ssd_scan_bhsp(x, dt, a, bm, cm, chunk=q, interpret=True)
    state = np.zeros((p, n))
    for t in range(s):
        da = float(dt[0, 0, t]) * float(a[0])
        state = state * np.exp(da) + float(dt[0, 0, t]) * np.outer(
            np.asarray(x[0, 0, t]), np.asarray(bm[0, t])
        )
    np.testing.assert_allclose(np.asarray(s_kernel[0, 0]), state, atol=1e-3)


# ------------------------------------------------------------------- quantize
@given(
    n=st.integers(1, 4000),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 100),
)
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale, seed):
    """|x - dq(q(x))| <= absmax/127/2 + eps per block, any shape."""
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale, np.float32
    )
    q, s = quantize_int8(jnp.asarray(x))
    xr = np.asarray(dequantize_int8(q, s))
    bound = np.abs(x).max() / 127.0 * 0.5001 + 1e-6
    assert np.abs(xr - x).max() <= bound * 1.01 + 1e-6


@pytest.mark.parametrize("rows,block", [(8, 256), (16, 128), (8, 512)])
def test_quantize_kernel_matches_ref(rows, block):
    x = jax.random.normal(jax.random.PRNGKey(4), (rows * 4, block)) * 10
    qk, sk = quantize_int8_2d(x, block=block, rows=rows, interpret=True)
    qr, sr = quantize_int8_2d_ref(x)
    assert np.array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_quantize_zero_block():
    x = jnp.zeros((8, 256))
    q, s = quantize_int8_2d(x, interpret=True)
    assert np.all(np.asarray(q) == 0)
    xr = dequantize_int8(q.reshape(-1), s[:, 0])
    assert np.all(np.asarray(xr) == 0)


# ------------------------------------------------------------------ waterfill
def _waterfill_case(seed, *, with_edges):
    """A padded max-min scenario: nc live lanes scattered across ncp slots,
    junk caps/indices in the dead lanes (the mask must neutralize them)."""
    rng = np.random.default_rng(seed)
    nv = int(rng.integers(2, 10))
    nc = int(rng.integers(1, 40))
    ncp = nc + int(rng.integers(0, 17))
    active = np.zeros(ncp, dtype=bool)
    active[rng.permutation(ncp)[:nc]] = True
    caps = np.where(active, rng.uniform(0.5, 8.0, ncp), 123.0)
    src = rng.integers(0, nv, ncp)
    dst = rng.integers(0, nv, ncp)
    eg = rng.uniform(1.0, 12.0, nv)
    inn = rng.uniform(1.0, 12.0, nv)
    if with_edges:
        ne = int(rng.integers(1, 5))
        eid = rng.integers(0, ne, ncp)
        ed = rng.uniform(2.0, 20.0, ne)
    else:
        ne, eid, ed = 0, np.zeros(ncp, dtype=np.int64), None
    return caps, src, dst, eg, inn, eid, ed, active, nv, ne


@pytest.mark.parametrize("with_edges", [False, True])
@pytest.mark.parametrize("seed", range(4))
def test_masked_waterfill_bitwise_vs_flowsim_oracle(seed, with_edges):
    """ref.masked_maxmin_rates on padded lanes is BITWISE the flowsim
    numpy water-filler on the compacted set (the f64 parity contract the
    jax sim engine stands on), and dead lanes come back exactly 0.0."""
    from jax.experimental import enable_x64

    from repro.kernels.waterfill.ref import masked_maxmin_rates
    from repro.transfer.flowsim import _maxmin_rates_arr

    caps, src, dst, eg, inn, eid, ed, active, nv, ne = _waterfill_case(
        seed, with_edges=with_edges,
    )
    want = _maxmin_rates_arr(
        caps[active], src[active], dst[active], eg, inn,
        eid[active] if ed is not None else None, ed,
    )
    with enable_x64():
        got = np.asarray(masked_maxmin_rates(
            jnp.asarray(caps), jnp.asarray(src), jnp.asarray(dst),
            jnp.asarray(eg), jnp.asarray(inn), jnp.asarray(eid),
            None if ed is None else jnp.asarray(ed),
            jnp.asarray(active), n_vms=nv, n_edges=ne,
        ))
    assert np.array_equal(got[active], want)
    assert np.all(got[~active] == 0.0)


@pytest.mark.parametrize("with_edges", [False, True])
@pytest.mark.parametrize("seed", range(3))
def test_pallas_waterfill_matches_oracle_f32(seed, with_edges):
    """The Pallas one-hot-matmul kernel (interpret mode off-TPU) tracks the
    f64 oracle to f32 tolerance, masked lanes included."""
    from repro.kernels.waterfill.ops import waterfill_rates
    from repro.transfer.flowsim import _maxmin_rates_arr

    caps, src, dst, eg, inn, eid, ed, active, nv, ne = _waterfill_case(
        seed, with_edges=with_edges,
    )
    want = _maxmin_rates_arr(
        caps[active], src[active], dst[active], eg, inn,
        eid[active] if ed is not None else None, ed,
    )
    got = np.asarray(waterfill_rates(
        caps, src, dst, eg, inn,
        eid if ed is not None else None, ed, active,
    ))
    np.testing.assert_allclose(got[active], want, rtol=5e-3, atol=5e-3)
    assert np.all(got[~active] == 0.0)
