"""Per-architecture smoke tests (the brief's reduced-config requirement):
one forward/train step on CPU asserting output shapes + no NaNs, plus
prefill/decode consistency for each stack family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import (
    count_params,
    decode_step,
    init_params,
    loss_fn,
    prefill,
)
from repro.sharding.specs import ShardingRules

RULES = ShardingRules(batch=None, fsdp=None, tp=None)
B, S = 2, 64


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_vlm:
        batch["vision"] = jax.random.normal(
            key, (B, cfg.num_vision_tokens, cfg.d_model)
        )
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(key, (B, cfg.num_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch):
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    @jax.jit
    def step(p, b):
        loss, metrics = loss_fn(cfg, RULES, p, b)
        grads = jax.grad(lambda q: loss_fn(cfg, RULES, q, b)[0])(p)
        return loss, grads

    loss, grads = step(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm), f"{arch}: non-finite grads"
    for p, g in zip(jax.tree.leaves(params), jax.tree.leaves(grads)):
        assert p.shape == g.shape


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_shapes(arch):
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    state, last_logits = jax.jit(
        lambda p, b: prefill(cfg, RULES, p, b, t_max=S + 4)
    )(params, batch)
    assert last_logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(last_logits).all()
    tok = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    logits, state2 = jax.jit(
        lambda p, s_, t: decode_step(cfg, RULES, p, s_, t)
    )(params, state, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert int(state2["pos"]) == int(state["pos"]) + 1


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-1.3b", "qwen3-moe-30b-a3b"])
def test_prefill_matches_forward(arch):
    """prefill's last-position logits == the train-mode forward's (same math,
    different cache plumbing)."""
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    from repro.models.model import forward
    from repro.models.layers import unembed_matrix

    h = jax.jit(lambda p, b: forward(cfg, RULES, p, b))(params, batch)
    w = unembed_matrix(cfg, params["embed"], h.dtype)
    ref_logits = jnp.einsum("bd,dv->bv", h[:, -1], w,
                            preferred_element_type=jnp.float32)
    _, last_logits = jax.jit(
        lambda p, b: prefill(cfg, RULES, p, b, t_max=S)
    )(params, batch)
    assert jnp.allclose(last_logits, ref_logits, atol=2e-2), (
        float(jnp.max(jnp.abs(last_logits - ref_logits)))
    )


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-1.3b", "zamba2-7b"])
def test_decode_matches_prefill_extension(arch):
    """decode(prefill(t[:s]), t[s]) logits == prefill(t[:s+1]) last logits —
    the KV/SSM caches carry exactly the information the full forward sees.
    Run in f32: the check is about cache *semantics*, and bf16 accumulation
    noise through stacked attention would otherwise mask real bugs."""
    cfg = dataclasses.replace(reduced(ARCHS[arch]), dtype="float32")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    full = _batch(cfg, key)
    toks = full["tokens"]

    short = dict(full)
    short["tokens"] = toks[:, : S - 1]
    state, _ = jax.jit(lambda p, b: prefill(cfg, RULES, p, b, t_max=S))(
        params, short
    )
    step_logits, _ = jax.jit(
        lambda p, s_, t: decode_step(cfg, RULES, p, s_, t)
    )(params, state, toks[:, S - 1 : S])

    _, ref_logits = jax.jit(lambda p, b: prefill(cfg, RULES, p, b, t_max=S))(
        params, full
    )
    err = float(jnp.max(jnp.abs(step_logits - ref_logits)))
    assert err < 1e-3, f"{arch}: decode/prefill divergence {err}"


def test_param_counts_match_published_sizes():
    expect = {
        "smollm-135m": (0.13e9, 0.15e9),
        "nemotron-4-340b": (3.2e11, 3.6e11),
        "mistral-large-123b": (1.18e11, 1.27e11),
        "qwen2-7b": (7.2e9, 8.0e9),
        "mixtral-8x22b": (1.3e11, 1.45e11),
        "qwen3-moe-30b-a3b": (2.9e10, 3.2e10),
        "mamba2-1.3b": (1.2e9, 1.45e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(ARCHS[arch])
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    n_act = count_params(ARCHS["qwen3-moe-30b-a3b"], active_only=True)
    assert 2.5e9 <= n_act <= 4.0e9  # "A3B"


def test_moe_psum_combine_matches_gather_combine():
    """§Perf v8: the scatter-from-experts + psum combine is numerically
    identical (values and grads) to the gather-based combine."""
    from repro.configs import MoEConfig
    from repro.models import loss_fn as _loss

    cfg0 = dataclasses.replace(
        reduced(ARCHS["qwen3-moe-30b-a3b"]),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                      capacity_factor=8.0),
        dtype="float32", moe_shard_dispatch=True,
    )
    cfg1 = dataclasses.replace(cfg0, moe_psum_combine=True)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg0, key)
    batch = {
        "tokens": jax.random.randint(key, (2, 32), 0, cfg0.vocab_size),
        "labels": jax.random.randint(key, (2, 32), 0, cfg0.vocab_size),
    }
    l0, _ = _loss(cfg0, RULES, params, batch)
    l1, _ = _loss(cfg1, RULES, params, batch)
    assert abs(float(l0) - float(l1)) < 1e-5
    g0 = jax.grad(lambda p: _loss(cfg0, RULES, p, batch)[0])(params)
    g1 = jax.grad(lambda p: _loss(cfg1, RULES, p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        assert float(jnp.abs(a - b).max()) < 1e-4
