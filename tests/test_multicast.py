"""Multicast equivalence suite (ISSUE 3): one-to-many distribution trees.

Pins the whole multicast stack together: planner (cost below the sum of
unicasts, single-destination bitwise equivalence, per-commodity flow
conservation, zero-re-assembly re-planning), both fluid simulators
(chunk-for-chunk on a 3-destination fan-out with a mid-transfer VM kill on
one branch), the real-bytes gateway (fan-out, per-destination verification,
zero-byte objects), and the checkpoint replicator's argument validation.
"""

import numpy as np
import pytest

from repro.core import default_topology, toy_topology
from repro.core import milp
from repro.core.plan import TransferPlan
from repro.core.planner import Planner
from repro.transfer import (
    LinkDegrade,
    TransferJob,
    TransferRequest,
    TransferService,
    VMFailure,
    simulate_multi,
    simulate_multi_reference,
)
from repro.transfer.gateway import BlobStore, transfer_objects_multicast

SRC = "gcp:us-central1"
# three destinations sharing a continent: the cross-continent trunk is the
# expensive hop, intra-EU fan-out is cheap — the scenario the envelope wins
DSTS = ["gcp:europe-west1", "gcp:europe-west3", "gcp:europe-west4"]
FLOOR = 2.0


@pytest.fixture(scope="module")
def top():
    return default_topology()


@pytest.fixture(scope="module")
def planner(top):
    return Planner(top, max_relays=6)


@pytest.fixture(scope="module")
def mc_plan(planner):
    return planner.plan_multicast_cost_min(SRC, DSTS, FLOOR, 4.0)


# ------------------------------------------------------------------- planner
def test_multicast_cost_below_sum_of_unicasts(top, planner, mc_plan):
    """Acceptance: at the same per-destination floor, the multicast plan
    costs no more than N unicast plans — and strictly less for three
    same-continent destinations (>= 25% egress savings)."""
    assert mc_plan.solver_status == "optimal"
    unis = [planner.plan_cost_min(SRC, d, FLOOR, 4.0) for d in DSTS]
    uni_total = sum(u.total_cost for u in unis)
    uni_egress = sum(u.egress_cost for u in unis)
    assert mc_plan.total_cost <= uni_total + 1e-9
    assert mc_plan.total_cost < uni_total * 0.999  # strictly lower
    assert mc_plan.egress_cost <= uni_egress * 0.75  # >= 25% egress savings


def test_multicast_plan_validates_per_commodity(mc_plan, top):
    assert mc_plan.validate() == []
    # every destination's floor is met by its own commodity
    for d in mc_plan.dsts:
        assert mc_plan.delivered_gbps(d) >= FLOOR * 0.99


def test_multicast_trees_cover_every_destination(mc_plan):
    trees = mc_plan.trees()
    assert trees
    rate = {d: 0.0 for d in mc_plan.dsts}
    for t in trees:
        assert t.rate > 0
        for d, path in t.paths.items():
            assert path[0] == mc_plan.src and path[-1] == d
            rate[d] += t.rate
    for d in mc_plan.dsts:
        assert rate[d] >= mc_plan.delivered_gbps(d) * 0.99


def test_single_destination_bitwise_matches_unicast(planner):
    uni = planner.plan_cost_min(SRC, DSTS[0], FLOOR, 4.0)
    one = planner.plan_multicast_cost_min(SRC, [DSTS[0]], FLOOR, 4.0)
    assert np.array_equal(one.F[0], uni.F)
    assert np.array_equal(one.G, uni.F)
    assert np.array_equal(one.N, uni.N)
    assert np.array_equal(one.M, uni.M)
    assert one.total_cost == pytest.approx(uni.total_cost, rel=1e-12)


def test_general_pipeline_single_dest_close_to_unicast(top, planner):
    """The generic D-commodity pipeline (not the delegation fast path) on
    one destination lands within ~1% of the unicast round-down."""
    from repro.core.solver.bnb import solve_multicast

    sub, s, ds, _ = planner._prune_mc(SRC, [DSTS[0]])
    res = solve_multicast(sub, s, ds, np.array([FLOOR]))
    uni = planner.plan_cost_min(SRC, DSTS[0], FLOOR, 4.0)
    assert res.ok
    # objective is $/s at the goal rate; compare per-GB at the same rate
    assert res.objective == pytest.approx(
        uni.total_cost / uni.transfer_time_s, rel=0.02
    )


def test_multicast_replan_is_pure_cache_hit(planner, top, mc_plan):
    """Acceptance: re-planning surviving branches on a degraded topology
    performs ZERO LP re-assembly (goals and cuts are pure RHS / extra
    rows on the cached MulticastLPStructure)."""
    s, d0 = top.index(SRC), top.index(DSTS[0])
    builds0 = milp.N_STRUCT_BUILDS
    replan = planner.plan_multicast_cost_min(
        SRC, DSTS, [0.0, FLOOR, FLOOR], 2.0,
        degraded_links={(s, d0): 0.3},
    )
    assert milp.N_STRUCT_BUILDS == builds0, "re-plan re-assembled a structure"
    assert replan.solver_status == "optimal"
    assert replan.validate() == []
    # the finished destination dropped out of the trees
    assert top.index(DSTS[0]) not in replan.active_dsts
    # the degraded 4b row binds the envelope
    phi_cap = 0.3 * top.tput[s, d0] * replan.M[s, d0] / top.limit_conn
    assert replan.G[s, d0] <= phi_cap + 1e-6


def test_multicast_tput_max_respects_ceiling(planner):
    plan = planner.plan_multicast_tput_max(SRC, DSTS, 0.16, 8.0, n_samples=4)
    assert plan.solver_status == "optimal"
    assert plan.cost_per_gb <= 0.16 + 1e-9
    assert plan.validate() == []
    # a ceiling below every feasible plan returns best-effort, flagged
    cheap = planner.plan_multicast_tput_max(SRC, DSTS, 0.01, 8.0,
                                            n_samples=4)
    assert cheap.solver_status == "cost_ceiling_infeasible"


def test_max_multicast_throughput_bounds_the_floor(planner):
    hi = planner.max_multicast_throughput(SRC, DSTS)
    assert hi > FLOOR
    with_cap = planner.plan_multicast_cost_min(SRC, DSTS, hi * 0.5, 1.0)
    assert with_cap.solver_status == "optimal"


# ---------------------------------------------------------------- simulators
def _kill_fault(plan, top, count=1):
    """A VM kill on one branch: pick the first destination region hosting
    gateway VMs so exactly one fan-out branch is hit."""
    for d in plan.dsts:
        if plan.N[d] >= 1:
            return VMFailure(t_s=1.5, job=0, region=int(d), count=count)
    raise AssertionError("plan provisioned no destination VMs")


@pytest.mark.parametrize("seed", [0, 3])
def test_multicast_sim_matches_reference_with_branch_kill(top, mc_plan, seed):
    """Acceptance: vectorized vs object-per-connection oracle, chunk for
    chunk, on a 3-destination fan-out with a mid-transfer VM kill on one
    branch — per-destination delivered counts, retries, costs."""
    jobs = [TransferJob(mc_plan, "repl")]
    faults = [_kill_fault(mc_plan, top)]
    new = simulate_multi(jobs, faults, seed=seed)
    ref = simulate_multi_reference(jobs, faults, seed=seed)
    a, b = new.jobs[0], ref.jobs[0]
    assert a.chunks_delivered == b.chunks_delivered
    assert a.retried_chunks == b.retried_chunks
    assert a.per_dst_delivered == b.per_dst_delivered
    assert a.status == b.status
    assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert new.time_s == pytest.approx(ref.time_s, rel=1e-9)
    assert a.retried_chunks > 0  # the kill really hit an in-flight chunk


def test_multicast_clean_run_delivers_everywhere(top, mc_plan):
    res = simulate_multi([TransferJob(mc_plan, "repl")], [], seed=0)
    j = res.jobs[0]
    assert j.status == "done"
    assert j.chunks_delivered == j.n_chunks
    assert set(j.per_dst_delivered) == set(mc_plan.dsts)
    for cnt in j.per_dst_delivered.values():
        assert cnt == j.n_chunks
    # shared-trunk accounting: the job moves far fewer bytes than three
    # independent unicasts would (< D x volume on the source-egress links)
    src_gb = sum(
        gb for e, gb in j.per_edge_gb.items()
        if e.startswith(f"{mc_plan.src}->")
    )
    assert src_gb < len(mc_plan.dsts) * mc_plan.volume_gb


def test_unequal_floor_multicast_completes(top, planner):
    """Regression: with unequal per-destination floors every tree must
    still span every active destination (commodity flows are normalized to
    the slowest branch) — previously chunks binned to a subset-serving
    tree could never reach the other destinations and the job stalled."""
    plan = planner.plan_multicast_cost_min(SRC, DSTS, [0.5, 2.0, 2.0], 1.0)
    assert plan.solver_status == "optimal" and plan.validate() == []
    for t in plan.trees():
        assert set(t.paths) == set(plan.active_dsts)
    jobs = [TransferJob(plan, "mc")]
    new = simulate_multi(jobs, [], seed=0)
    ref = simulate_multi_reference(jobs, [], seed=0)
    j = new.jobs[0]
    assert j.status == "done"
    assert all(v == j.n_chunks for v in j.per_dst_delivered.values())
    assert j.per_dst_delivered == ref.jobs[0].per_dst_delivered
    assert ref.jobs[0].status == "done"


def test_multicast_and_unicast_jobs_share_the_plane(top, planner, mc_plan):
    """A multicast tenant and a unicast tenant co-exist in one multi-job
    scenario; both sims agree on both."""
    from repro.core import direct_plan

    jobs = [
        TransferJob(mc_plan, "mc"),
        TransferJob(direct_plan(top, "aws:us-west-2", "aws:eu-central-1",
                                2.0, num_vms=2), "uni", arrival_s=0.5),
    ]
    new = simulate_multi(jobs, [], seed=1)
    ref = simulate_multi_reference(jobs, [], seed=1)
    for a, b in zip(new.jobs, ref.jobs):
        assert a.chunks_delivered == b.chunks_delivered
        assert a.status == b.status == "done"
        assert a.per_dst_delivered == b.per_dst_delivered


def test_event_exactly_at_horizon_is_classified_consistently(top, mc_plan):
    """Regression (epsilon unification): a scripted event landing EXACTLY
    on the horizon must be classified the same way by both simulators —
    previously three different tolerances could disagree at the boundary."""
    s, d0 = top.index(SRC), mc_plan.dsts[0]
    horizon = 2.0
    faults = [LinkDegrade(t_s=horizon, src=s, dst=int(d0), factor=0.5)]
    jobs = [TransferJob(mc_plan, "repl")]
    new = simulate_multi(jobs, faults, seed=0, horizon_s=horizon)
    ref = simulate_multi_reference(jobs, faults, seed=0, horizon_s=horizon)
    assert new.time_s == pytest.approx(horizon)
    assert ref.time_s == pytest.approx(horizon)
    assert new.jobs[0].status == ref.jobs[0].status == "running"
    assert new.jobs[0].chunks_delivered == ref.jobs[0].chunks_delivered
    assert new.jobs[0].per_dst_delivered == ref.jobs[0].per_dst_delivered
    # an arrival exactly at the horizon is seen by both (status not
    # "pending") but moves nothing
    late = [TransferJob(mc_plan, "late", arrival_s=horizon)]
    a = simulate_multi(late, [], seed=0, horizon_s=horizon).jobs[0]
    b = simulate_multi_reference(late, [], seed=0, horizon_s=horizon).jobs[0]
    assert a.status == b.status
    assert a.chunks_delivered == b.chunks_delivered == 0


# ------------------------------------------------------------------- gateway
def test_gateway_multicast_zero_byte_objects_reach_all_destinations(
    top, mc_plan
):
    src_store = BlobStore()
    rng = np.random.default_rng(7)
    keys = ["a", "empty", "b"]
    src_store.put("a", rng.bytes(200_000))
    src_store.put("empty", b"")
    src_store.put("b", rng.bytes(70_000))
    stores = {top.keys()[d]: BlobStore() for d in mc_plan.dsts}
    rep = transfer_objects_multicast(
        mc_plan, src_store, stores, keys, chunk_bytes=1 << 16
    )
    assert rep.chunks_missing == 0 and rep.checksum_failures == 0
    for key_region, store in stores.items():
        assert sorted(store.keys()) == sorted(keys)
        for k in keys:
            assert store.get(k) == src_store.get(k)
        assert store.get("empty") == b""
        assert rep.per_dest[key_region].chunks_missing == 0


def test_gateway_multicast_per_destination_resume(top, mc_plan):
    """A destination that already holds a verified object skips it while
    the others still receive it."""
    src_store = BlobStore()
    rng = np.random.default_rng(8)
    src_store.put("x", rng.bytes(150_000))
    names = [top.keys()[d] for d in mc_plan.dsts]
    stores = {n: BlobStore() for n in names}
    stores[names[0]].put("x", src_store.get("x"))  # pre-seeded
    rep = transfer_objects_multicast(
        mc_plan, src_store, stores, ["x"], chunk_bytes=1 << 16
    )
    assert rep.per_dest[names[0]].objects_skipped == 1
    assert rep.per_dest[names[1]].objects_skipped == 0
    for n in names:
        assert stores[n].get("x") == src_store.get("x")


# ------------------------------------------------------------------- service
def test_service_multicast_replans_surviving_branches(top):
    svc = TransferService(top, backend="jax", max_relays=6)
    svc.submit(TransferRequest("repl", SRC, "", 3.0, FLOOR, dsts=DSTS))
    s, d0 = top.index(SRC), top.index(DSTS[0])
    rep = svc.run(faults=[LinkDegrade(t_s=3.0, src=s, dst=d0, factor=0.2)])
    (job,) = rep.jobs
    assert job.status == "done"
    assert job.delivered_gb == pytest.approx(3.0, rel=0.02)
    assert job.replans, "the degraded trunk must force a re-plan"
    for r in job.replans:
        assert r.structure_builds == 0, "re-plan re-assembled an LPStructure"
        assert r.plan.solver_status == "optimal"


def test_service_replan_backs_off_goal_before_failing(top, monkeypatch):
    """Satellite: a non-optimal constrained solve no longer fails the job
    outright — the service retries with a backed-off goal and records the
    degraded SLO in the ReplanRecord."""
    import dataclasses

    svc = TransferService(top, backend="jax", max_relays=6)
    svc.submit(TransferRequest("a", "aws:us-west-2", "aws:eu-central-1",
                               2.0, 4.0))
    orig = svc.planner.plan

    def flaky(spec):
        plan = orig(spec)
        if spec.degraded_links and (spec.tput_goal_gbps or 0.0) > 1.5:
            # degenerate solver stall at high goals on the degraded grid
            return dataclasses.replace(plan, solver_status="max_iter")
        return plan

    monkeypatch.setattr(svc.planner, "plan", flaky)
    s, d = top.index("aws:us-west-2"), top.index("aws:eu-central-1")
    rep = svc.run(faults=[LinkDegrade(t_s=2.0, src=s, dst=d, factor=0.3)])
    (job,) = rep.jobs
    assert job.replans
    rec = job.replans[-1]
    assert rec.backoffs > 0 and rec.degraded_slo
    assert rec.goal_gbps < 4.0 * 0.96  # the accepted goal was backed off
    assert rec.plan.solver_status == "optimal"
    assert job.status == "done"


# ----------------------------------------------------------------- satellite
def test_replicate_rejects_both_planner_modes(tmp_path, top):
    from repro.ckpt import replicate_checkpoint

    (tmp_path / "f").write_bytes(b"x" * 128)
    stores = {d: BlobStore() for d in DSTS}
    with pytest.raises(ValueError, match="at most one"):
        replicate_checkpoint(
            tmp_path, top, SRC, DSTS, stores,
            cost_ceiling_per_gb=0.1, tput_floor_gbps=1.0,
        )


def test_replicate_fails_fast_on_missing_store(tmp_path, top):
    from repro.ckpt import replicate_checkpoint

    (tmp_path / "f").write_bytes(b"x" * 128)
    stores = {DSTS[0]: BlobStore()}  # two destinations missing
    with pytest.raises(ValueError, match="missing from dst_stores"):
        replicate_checkpoint(
            tmp_path, top, SRC, DSTS, stores, tput_floor_gbps=1.0
        )


def test_paths_decomposes_all_flow_beyond_old_cap():
    """Regression: a plan whose decomposition needs more than 32 paths no
    longer silently drops the residual flow."""
    n = 44
    top = toy_topology(n=n, seed=1)
    src, dst = 0, 1
    F = np.zeros((n, n))
    relays = list(range(2, 42))  # 40 parallel two-hop paths
    for r in relays:
        F[src, r] = 1.0
        F[r, dst] = 1.0
    plan = TransferPlan(
        top=top, src=src, dst=dst, tput_goal=40.0, volume_gb=1.0,
        F=F, N=np.ones(n), M=np.ones((n, n)),
    )
    paths = plan.paths()
    assert len(paths) == len(relays)
    assert sum(f for _, f in paths) == pytest.approx(40.0)
    # an explicit cap that drops flow warns instead of staying silent
    with pytest.warns(UserWarning, match="under-provision"):
        short = plan.paths(max_paths=8)
    assert len(short) == 8
