"""Multi-job fluid data plane: fault schedules, contention, oracle parity."""

import numpy as np
import pytest

from repro.core import default_topology, direct_plan
from repro.transfer import (
    LinkDegrade,
    TransferJob,
    VMFailure,
    simulate_multi,
    simulate_multi_reference,
)

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "gcp:us-central1"


@pytest.fixture(scope="module")
def top():
    return default_topology()


def _jobs(top, volume=2.0, arrivals=(0.0, 1.0, 0.5)):
    return [
        TransferJob(direct_plan(top, SRC, DST, volume, num_vms=2), "a",
                    arrival_s=arrivals[0]),
        TransferJob(direct_plan(top, SRC, DST, volume, num_vms=2), "b",
                    arrival_s=arrivals[1]),
        TransferJob(direct_plan(top, SRC2, DST, volume, num_vms=2), "c",
                    arrival_s=arrivals[2]),
    ]


def _fault_schedule(top):
    s, d = top.index(SRC), top.index(DST)
    return [
        LinkDegrade(t_s=2.0, src=s, dst=d, factor=0.5),
        VMFailure(t_s=3.0, job=0, region=s, count=1),
    ]


@pytest.mark.parametrize("seed,faulted", [(0, False), (0, True), (3, True)])
def test_vectorized_multi_matches_reference(top, seed, faulted):
    """Acceptance: the vectorized loop reproduces the object-per-connection
    oracle chunk-for-chunk on the fault schedules — per-job delivered
    counts identical, retries identical, costs within float-noise."""
    jobs = _jobs(top)
    faults = _fault_schedule(top) if faulted else []
    new = simulate_multi(jobs, faults, seed=seed)
    ref = simulate_multi_reference(jobs, faults, seed=seed)
    for a, b in zip(new.jobs, ref.jobs):
        assert a.chunks_delivered == b.chunks_delivered
        assert a.retried_chunks == b.retried_chunks
        assert a.status == b.status
        assert a.tput_gbps == pytest.approx(b.tput_gbps, rel=1e-9)
        assert a.total_cost == pytest.approx(b.total_cost, rel=1e-9)
    assert new.time_s == pytest.approx(ref.time_s, rel=1e-9)


def test_horizon_cut_matches_reference(top):
    jobs = _jobs(top)
    new = simulate_multi(jobs, _fault_schedule(top), seed=1, horizon_s=4.0)
    ref = simulate_multi_reference(
        jobs, _fault_schedule(top), seed=1, horizon_s=4.0
    )
    assert new.time_s == pytest.approx(4.0)
    for a, b in zip(new.jobs, ref.jobs):
        assert a.chunks_delivered == b.chunks_delivered
        assert a.status == b.status
        assert a.status in ("running", "done")
    assert any(j.status == "running" for j in new.jobs)


def test_vm_failure_zero_loss_no_duplicates(top):
    """A gateway-VM kill mid-transfer loses no chunk and delivers none
    twice: every job still lands exactly n_chunks, with retries > 0."""
    jobs = _jobs(top)
    res = simulate_multi(jobs, _fault_schedule(top), seed=0)
    assert all(j.status == "done" for j in res.jobs)
    for j in res.jobs:
        assert j.chunks_delivered == j.n_chunks  # zero loss, no double count
    assert res.jobs[0].retried_chunks > 0  # the kill actually hit in-flight


def test_delayed_arrival_starts_late(top):
    jobs = _jobs(top, arrivals=(0.0, 4.0, 0.0))
    res = simulate_multi(jobs, [], seed=0)
    assert all(j.status == "done" for j in res.jobs)
    # job b arrived at t=4: its measured duration excludes the wait
    assert res.time_s >= 4.0
    assert res.jobs[1].time_s <= res.time_s - 4.0 + 1e-6


def test_link_contention_slows_tenants_down(top):
    """Two jobs sharing a wide-area pair under max-min fairness each run
    slower than the same job alone on the link."""
    # scale 0.4: the shared pair sustains ~2 Gbps — one tenant fits, two
    # must split it max-min
    solo = simulate_multi(
        [TransferJob(direct_plan(top, SRC, DST, 2.0, num_vms=2), "solo")],
        seed=0, link_capacity_scale=0.4,
    )
    pair = simulate_multi(
        [
            TransferJob(direct_plan(top, SRC, DST, 2.0, num_vms=2), "a"),
            TransferJob(direct_plan(top, SRC, DST, 2.0, num_vms=2), "b"),
        ],
        seed=0, link_capacity_scale=0.4,
    )
    assert all(j.status == "done" for j in pair.jobs)
    for j in pair.jobs:
        assert j.tput_gbps < solo.jobs[0].tput_gbps * 0.75


def test_link_degrade_reduces_throughput(top):
    jobs = [TransferJob(direct_plan(top, SRC, DST, 2.0, num_vms=2), "a")]
    s, d = top.index(SRC), top.index(DST)
    clean = simulate_multi(jobs, [], seed=2)
    degraded = simulate_multi(
        jobs, [LinkDegrade(t_s=1.0, src=s, dst=d, factor=0.25)], seed=2
    )
    assert degraded.jobs[0].status == "done"
    assert degraded.time_s > clean.time_s * 1.2


def test_total_vm_kill_stalls_job_without_poisoning_others(top):
    """Killing every source VM of one job stalls it; co-tenants finish."""
    jobs = _jobs(top)
    s = top.index(SRC)
    res = simulate_multi(
        jobs, [VMFailure(t_s=1.0, job=0, region=s, count=2)], seed=0
    )
    assert res.jobs[0].status == "stalled"
    assert res.jobs[0].chunks_delivered < res.jobs[0].n_chunks
    assert res.jobs[1].status == "done"
    assert res.jobs[2].status == "done"
    ref = simulate_multi_reference(
        jobs, [VMFailure(t_s=1.0, job=0, region=s, count=2)], seed=0
    )
    assert [j.chunks_delivered for j in res.jobs] == [
        j.chunks_delivered for j in ref.jobs
    ]
    assert [j.status for j in res.jobs] == [j.status for j in ref.jobs]


def test_multi_egress_accounting_sums_to_chunk_volume(top):
    jobs = _jobs(top)
    res = simulate_multi(jobs, _fault_schedule(top), seed=0)
    for j in res.jobs:
        moved_gb = sum(j.per_edge_gb.values())
        min_gb = j.n_chunks * (16.0 / 1024.0)  # one traversal of each chunk
        assert moved_gb >= min_gb * 0.99
        assert j.egress_cost > 0 and j.vm_cost > 0
        assert np.isfinite(j.total_cost)
