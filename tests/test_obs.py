"""Skytrace observability plane: registry, tracer, export, determinism.

Pins the PR-9 invariants: the same seed produces a byte-identical
Chrome-trace across processes, the vectorized and reference simulators
emit identical sim-event streams, the ring buffer bounds memory, and the
disabled tracer is a no-op.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.obs import (
    REGISTRY,
    disable,
    enable,
    get_registry,
    get_tracer,
    text_timeline,
    to_chrome_trace,
    trace_json,
)
from repro.obs.__main__ import trace_chaos_scenario
from repro.obs.metrics import Counter, Gauge, Histogram

SRC_DIR = Path(__file__).resolve().parent.parent / "src"


# ---------------------------------------------------------------- metrics


def test_registry_get_or_create_and_type_conflict():
    c = REGISTRY.counter("test.hits")
    assert isinstance(c, Counter)
    assert REGISTRY.counter("test.hits") is c  # same instrument back
    with pytest.raises(TypeError, match="already registered"):
        REGISTRY.gauge("test.hits")


def test_snapshot_skips_empty_and_filters_by_prefix():
    REGISTRY.counter("alpha.hits").inc(3)
    REGISTRY.counter("alpha.misses")  # never incremented: absent
    REGISTRY.gauge("alpha.depth").set(2.5)
    REGISTRY.histogram("beta.lat_s").observe(0.25)
    REGISTRY.histogram("beta.lat_s").observe(0.75)
    snap = REGISTRY.snapshot(("alpha.",))
    assert snap == {"alpha.hits": 3, "alpha.depth": 2.5}
    hist = REGISTRY.snapshot(("beta.",))["beta.lat_s"]
    assert hist == {"count": 2, "total": 1.0, "min": 0.25, "max": 0.75}
    full = REGISTRY.snapshot()
    assert "alpha.hits" in full and "beta.lat_s" in full


def test_reset_zeroes_in_place_so_cached_refs_stay_live():
    c = REGISTRY.counter("test.cached")
    g = REGISTRY.gauge("test.gauge")
    h = REGISTRY.histogram("test.hist")
    c.inc(7)
    g.set(1.0)
    h.observe(4.0)
    get_registry().reset()
    assert c.value == 0 and g.value == 0.0 and h.count == 0
    c.inc()  # the pre-reset reference still feeds the registry
    assert REGISTRY.counter("test.cached").value == 1
    assert REGISTRY.snapshot(("test.gauge",)) == {}  # gauge unset again


def test_milp_struct_builds_alias_tracks_registry_counter():
    from repro.core import Planner, PlanSpec, milp, toy_topology

    b0 = milp.N_STRUCT_BUILDS
    assert b0 == REGISTRY.counter("planner.struct_builds").value
    top = toy_topology(n=4, seed=11)
    Planner(top, max_relays=2).plan(PlanSpec(
        objective="cost_min", src="toy:r0", dst="toy:r1",
        tput_goal_gbps=1.0, volume_gb=0.01,
    ))
    built = milp.N_STRUCT_BUILDS - b0
    assert built >= 1  # fresh topology: at least one structure build
    assert milp.N_STRUCT_BUILDS == (
        REGISTRY.counter("planner.struct_builds").value
    )


# ----------------------------------------------------------------- tracer


def test_ring_buffer_bounds_memory_keeping_newest():
    tr = enable(capacity=8)
    for i in range(20):
        tr.instant("tick", float(i))
    assert len(tr) == 8
    names_ts = [e[2] for e in tr.events()]
    assert names_ts == [float(i) for i in range(12, 20)]  # oldest dropped
    tr.clear()
    assert len(tr) == 0


def test_disabled_tracer_is_a_noop():
    disable()
    tr = get_tracer()
    assert tr.enabled is False
    tr.instant("x", 0.0)
    tr.span("y", 0.0, 1.0)
    tr.sample("z", 0.0, 3)
    assert len(tr) == 0 and tr.events() == []


def test_enable_installs_and_disable_restores():
    tr = enable(capacity=4)
    assert get_tracer() is tr and tr.enabled
    disable()
    assert get_tracer().enabled is False


# ----------------------------------------------------------------- export


def test_chrome_trace_shape_and_canonical_json():
    events = [
        ("X", "work", 0.0015, 0.0000004, "planner", {"n": 2}),
        ("i", "mark", 0.002, 0.0, "sim", None),
        ("C", "queue", 0.003, 0.0, "sim", {"value": 5}),
    ]
    doc = to_chrome_trace(events)
    assert doc["displayTimeUnit"] == "ms"
    meta, meta2, span, mark, ctr = doc["traceEvents"]
    assert meta["ph"] == "M" and meta["args"] == {"name": "planner"}
    assert meta2["ph"] == "M" and meta2["args"] == {"name": "sim"}
    assert span == {
        "name": "work", "ph": "X", "ts": 1500, "pid": 1, "tid": 1,
        "dur": 1, "args": {"n": 2},  # sub-µs spans still render (dur >= 1)
    }
    assert mark["tid"] == 2 and "args" not in mark  # second track -> tid 2
    assert ctr["args"] == {"value": 5}
    payload = trace_json(events)
    assert payload == json.dumps(
        doc, sort_keys=True, separators=(",", ":")
    )
    assert json.loads(payload) == doc


def test_text_timeline_renders_and_limits():
    events = [
        ("i", "a", 0.001, 0.0, "sim", None),
        ("X", "b", 0.002, 0.004, "sim", {"job": 1}),
    ]
    text = text_timeline(events)
    lines = text.splitlines()
    assert len(lines) == 2
    assert "[sim] a" in lines[0]
    assert "b +4.000ms job=1" in lines[1]
    assert text_timeline(events, limit=1).splitlines() == [lines[1]]


# ----------------------------------------------------- determinism pins


def test_flowsim_and_reference_emit_identical_traces():
    fast = trace_chaos_scenario(seed=5, volume_gb=0.5, horizon_s=8.0)
    ref = trace_chaos_scenario(
        seed=5, volume_gb=0.5, horizon_s=8.0, reference=True
    )
    assert len(fast) > 10
    assert {e[4] for e in fast} == {"sim"}  # sim-time events only
    assert trace_json(fast) == trace_json(ref)


def test_same_seed_same_process_is_deterministic():
    a = trace_chaos_scenario(seed=2, volume_gb=0.5, horizon_s=8.0)
    b = trace_chaos_scenario(seed=2, volume_gb=0.5, horizon_s=8.0)
    assert trace_json(a) == trace_json(b)
    c = trace_chaos_scenario(seed=3, volume_gb=0.5, horizon_s=8.0)
    assert trace_json(a) != trace_json(c)  # the seed actually matters


def test_cli_export_is_byte_identical_across_processes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC_DIR)
    outs = []
    for run in ("a", "b"):
        out = tmp_path / f"trace-{run}.json"
        res = subprocess.run(
            [
                sys.executable, "-m", "repro.obs", "--seed", "9",
                "--volume-gb", "0.5", "--horizon-s", "8",
                "--out", str(out),
            ],
            env=env, capture_output=True, text=True, timeout=300,
        )
        assert res.returncode == 0, res.stderr
        outs.append(out.read_bytes())
    assert outs[0] == outs[1]
    doc = json.loads(outs[0])  # and it is valid Chrome-trace JSON
    assert doc["traceEvents"][0]["ph"] == "M"
    assert any(e["ph"] == "i" for e in doc["traceEvents"])
