"""Planner behaviour on the embedded 71-region topology (paper §4-§5)."""

import numpy as np
import pytest

from repro.core import (
    Planner,
    default_topology,
    direct_plan,
    gridftp_plan,
    ron_plan,
)

SRC, DST = "azure:canadacentral", "gcp:asia-northeast1"  # paper Fig. 1 route


@pytest.fixture(scope="module")
def top():
    return default_topology()


@pytest.fixture(scope="module")
def planner(top):
    return Planner(top)


def test_grid_shape_and_region_counts(top):
    by = {}
    for r in top.regions:
        by[r.provider] = by.get(r.provider, 0) + 1
    assert by == {"aws": 20, "azure": 24, "gcp": 27}  # paper §7.1 scale
    v = top.num_regions
    assert top.tput.shape == (v, v) and (np.diag(top.tput) == 0).all()
    off = ~np.eye(v, dtype=bool)
    assert (top.tput[off] > 0).all() and (top.price_egress[off] > 0).all()


def test_egress_caps_respected(top):
    """AWS 5 Gbps / GCP 7 Gbps inter-cloud caps (paper §2, Fig. 3)."""
    for i, a in enumerate(top.regions):
        for j, b in enumerate(top.regions):
            if i == j or a.provider == b.provider:
                continue
            cap = {"aws": 5.0, "gcp": 7.0, "azure": 16.0}[a.provider]
            assert top.tput[i, j] <= cap + 1e-9


def test_cost_min_plan_is_feasible(planner):
    plan = planner.plan_cost_min(SRC, DST, 20.0, 50.0)
    assert plan.validate() == []
    assert plan.throughput >= 20.0 * 0.97  # round-down shortfall <= ~1%


def test_overlay_beats_direct_on_fig1_route(planner, top):
    """The paper's headline: ~2x speedup at ~1.2x cost via a relay."""
    dp = direct_plan(top, SRC, DST, 50.0)
    plan = planner.plan_tput_max(SRC, DST, dp.cost_per_gb * 1.25, 50.0,
                                 n_samples=12)
    assert plan.validate() == []
    assert plan.throughput > 1.5 * dp.throughput
    assert plan.cost_per_gb <= dp.cost_per_gb * 1.25 + 1e-6
    # and it actually uses a relay
    assert any(len(path) > 2 for path, _ in plan.paths())


def test_tput_max_respects_cost_ceiling(planner, top):
    dp = direct_plan(top, SRC, DST, 50.0)
    for mult in (1.05, 1.5):
        plan = planner.plan_tput_max(SRC, DST, dp.cost_per_gb * mult, 50.0,
                                     n_samples=10)
        assert plan.cost_per_gb <= dp.cost_per_gb * mult + 1e-6


def test_pareto_frontier_monotone(planner):
    pts = planner.pareto_frontier(SRC, DST, 50.0, n_samples=10)
    tputs = [p.tput_goal for p in pts]
    costs = [p.cost_per_gb for p in pts]
    assert tputs == sorted(tputs)
    # cost per GB is non-decreasing along the frontier (within solver noise)
    for a, b in zip(costs[:-1], costs[1:]):
        assert b >= a - 1e-4


def test_ron_is_fast_but_expensive(planner, top):
    """Table 2 directionality: RON beats direct on tput, Skyplane cost-opt
    beats RON on cost."""
    ron = ron_plan(top, SRC, DST, 50.0, num_vms=8)
    dp = direct_plan(top, SRC, DST, 50.0)
    assert ron.validate() == []
    assert ron.throughput >= dp.throughput
    sky = planner.plan_cost_min(SRC, DST, dp.throughput, 50.0)
    assert sky.cost_per_gb <= ron.cost_per_gb + 1e-9


def test_baselines_valid(top):
    for plan in (direct_plan(top, SRC, DST, 10.0), gridftp_plan(top, SRC, DST, 10.0)):
        assert plan.validate() == []
        assert len(plan.paths()) == 1  # direct only


def test_flow_decomposition_covers_throughput(planner):
    plan = planner.plan_cost_min(SRC, DST, 25.0, 50.0)
    total = sum(f for _, f in plan.paths())
    assert total == pytest.approx(plan.throughput, rel=1e-3)
