"""Hypothesis property tests on the planner's invariants."""


try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallback sweeps instead
    from _hypothesis_shim import HealthCheck, given, settings, strategies as st

from repro.core import Planner, toy_topology
from repro.core.solver.bnb import solve_milp

_SETTINGS = dict(
    max_examples=15,
    deadline=None,
    derandomize=True,  # deterministic CI; bump max_examples to explore
    suppress_health_check=[HealthCheck.too_slow],
)


@given(
    seed=st.integers(0, 10_000),
    n=st.integers(4, 7),
    frac=st.floats(0.1, 0.9),
)
@settings(**_SETTINGS)
def test_any_feasible_plan_satisfies_all_constraints(seed, n, frac):
    """Whatever topology we throw at it, a returned plan is 4b-4j feasible
    and achieves ~the goal (paper's <=1% round-down gap)."""
    top = toy_topology(n=n, seed=seed)
    planner = Planner(top)
    src, dst = top.keys()[0], top.keys()[1]
    hi = planner.max_throughput(src, dst)
    if hi <= 0.1:
        return
    goal = max(hi * frac, 1e-3)
    plan = planner.plan_cost_min(src, dst, goal, volume_gb=1.0)
    if plan.solver_status != "optimal":
        return
    assert plan.validate() == []
    assert plan.throughput >= min(goal, plan.tput_goal) * 0.999
    # integerization shortfall scales with connection granularity: flooring
    # M can cost ~1/limit_conn of each endpoint's capacity (toy topologies
    # use limit_conn=8 -> up to ~25%; at the paper's 64 this is the <=1%-
    # class gap of §5.1.3, checked separately in test_solver.py)
    assert plan.tput_goal >= goal * (1.0 - 3.0 / top.limit_conn)


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_running_cost_monotone_in_throughput_goal(seed):
    """The LP optimum ($/s while the transfer runs, Eq. 4a unscaled) is
    non-decreasing in the throughput floor: raising the floor only shrinks
    the feasible region. (Note: $/GB is NOT monotone — fixed VM cost
    amortizes worse at low rates — which hypothesis duly discovered when an
    earlier version of this test asserted it.)"""
    top = toy_topology(n=5, seed=seed)
    src, dst = 0, 1
    planner = Planner(top)
    hi = planner.max_throughput(top.keys()[0], top.keys()[1])
    if hi <= 0.2:
        return
    lo = solve_milp(top, src, dst, hi * 0.3, mode="relaxed")
    hi_ = solve_milp(top, src, dst, hi * 0.8, mode="relaxed")
    if lo.ok and hi_.ok:
        # 5% slack for the integer round-down on each side
        assert lo.objective <= hi_.objective * 1.05 + 1e-9


@given(seed=st.integers(0, 10_000), budget=st.floats(1.0, 16.0))
@settings(**_SETTINGS)
def test_more_vms_never_reduce_max_flow(seed, budget):
    top_small = toy_topology(n=5, seed=seed, limit_vm=2)
    top_big = toy_topology(n=5, seed=seed, limit_vm=4)
    p_small = Planner(top_small)
    p_big = Planner(top_big)
    src, dst = top_small.keys()[0], top_small.keys()[1]
    assert p_big.max_throughput(src, dst) >= p_small.max_throughput(src, dst) - 1e-6


@given(data=st.data())
@settings(**_SETTINGS)
def test_exact_never_worse_than_rounding(data):
    seed = data.draw(st.integers(0, 500))
    top = toy_topology(n=5, seed=seed)
    planner = Planner(top)
    hi = planner.max_throughput(top.keys()[0], top.keys()[1])
    if hi <= 0.2:
        return
    goal = hi * data.draw(st.floats(0.2, 0.8))
    rel = solve_milp(top, 0, 1, goal, mode="relaxed")
    ex = solve_milp(top, 0, 1, goal, mode="exact")
    if rel.ok and ex.ok:
        assert ex.objective <= rel.objective + 1e-9
