"""Probe-policy engine (ISSUE 5): pluggable probe scheduling, per-provider
drift priors, belief epoch rolls, multicast gateway telemetry."""

import numpy as np
import pytest

from repro.calibrate import (
    BeliefGrid,
    CalibratedTransferService,
    Calibrator,
    DriftModel,
    PolicyContext,
    ProbeBudget,
    make_policy,
)
from repro.calibrate.policies import POLICY_NAMES
from repro.core import Planner, default_topology, milp, toy_topology
from repro.core.profiles import (
    DEFAULT_DRIFT_PRIOR,
    PROVIDER_DRIFT_PRIOR,
    prior_rel_sigma_grid,
)
from repro.transfer import TransferRequest

SRC, DST = "aws:us-west-2", "aws:eu-central-1"


@pytest.fixture(scope="module")
def top():
    return default_topology()


@pytest.fixture(scope="module")
def truth(top):
    return DriftModel(top, seed=11, drift_sigma=0.3,
                      diurnal_amp=0.0).tput_at(500.0)


# ----------------------------------------------------------------- policies
def test_make_policy_names_and_unknown():
    for name in POLICY_NAMES:
        pol = make_policy(name, seed=3)
        assert pol.name == name
    with pytest.raises(ValueError, match="unknown probe policy"):
        make_policy("thompson")


@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_every_policy_respects_probe_budget_exactly(top, truth, policy):
    """Acceptance: the round's $ / seconds / count caps hold under every
    scheduler — budget enforcement lives in the Calibrator, not in the
    policy, so no ranking can overspend."""
    budget = ProbeBudget(usd_per_round=0.08, seconds_per_round=15.0,
                         max_probes_per_round=3)
    bel = BeliefGrid(top)
    cal = Calibrator(bel, policy=make_policy(policy, seed=5), budget=budget)
    pl = Planner(top, max_relays=6)
    for k in range(4):
        rnd = cal.run_round(float(k), truth, planner=pl,
                            contexts=[(SRC, DST)])
        assert rnd.cost_usd <= budget.usd_per_round + 1e-12
        assert rnd.n_probes <= budget.max_probes_per_round
        assert rnd.n_probes > 0
        assert rnd.policy == policy
        for r in rnd.records:
            assert r.duration_s <= budget.seconds_per_round + 1e-12
            assert r.cost_usd > 0


def test_epsilon_greedy_is_seed_deterministic(top, truth):
    """Same seed -> bitwise-identical probe schedule; a different seed
    explores differently."""
    pl = Planner(top, max_relays=6)
    budget = ProbeBudget(usd_per_round=1.0, seconds_per_round=30.0,
                         max_probes_per_round=4)

    def schedule(seed):
        bel = BeliefGrid(top)
        cal = Calibrator(
            bel, budget=budget,
            policy=make_policy("epsilon_greedy", seed=seed, epsilon=0.5),
        )
        out = []
        for k in range(4):
            rnd = cal.run_round(float(k), truth, planner=pl,
                                contexts=[(SRC, DST)])
            out.append(tuple((r.src, r.dst) for r in rnd.records))
        return out

    assert schedule(7) == schedule(7)
    assert schedule(7) != schedule(8)


def test_round_robin_guarantees_staleness_coverage(top, truth):
    """The LRU sweep must touch EVERY candidate within
    ceil(candidates / probes-per-round) rounds — the coverage guarantee
    score-driven policies do not give."""
    pl = Planner(top, max_relays=6)
    bel = BeliefGrid(top)
    cal = Calibrator(bel, policy="round_robin",
                     budget=ProbeBudget(usd_per_round=100.0,
                                        seconds_per_round=60.0,
                                        max_probes_per_round=6))
    candidates = cal.candidate_links(pl, [(SRC, DST)])
    probed = set()
    rounds = int(np.ceil(len(candidates) / 6))
    for k in range(rounds):
        rnd = cal.run_round(float(k), truth, planner=pl,
                            contexts=[(SRC, DST)])
        probed |= {(r.src, r.dst) for r in rnd.records}
    assert probed == set(candidates)


def test_evoi_zero_struct_builds_when_warm(top, truth):
    """Acceptance: the EVOI policy's LP evaluations ride the planner's
    cached structures — after the first round, ranking assembles
    nothing."""
    pl = Planner(top, max_relays=6)
    bel = BeliefGrid(top)
    cal = Calibrator(bel, policy="evoi")
    cal.run_round(0.0, truth, planner=pl, contexts=[(SRC, DST)])  # warm
    builds0 = milp.N_STRUCT_BUILDS
    cal.run_round(1.0, truth, planner=pl, contexts=[(SRC, DST)])
    assert milp.N_STRUCT_BUILDS == builds0, "EVOI re-assembled an LP"


def test_evoi_prioritizes_stale_plan_links(top):
    """Every candidate was just re-measured except the link carrying the
    plan's flow, whose confidence has gone stale: its re-opened LCB/mean
    gap is the regret the robust plan pays, so EVOI must rank
    re-measuring it first."""
    pl = Planner(top, max_relays=6)
    plan = pl.plan_cost_min(SRC, DST, 4.0, 8.0)
    bel = BeliefGrid(top)
    links = Calibrator(bel).candidate_links(pl, [(SRC, DST)])
    a, b = max(
        ((a, b) for a, b in links if plan.F[a, b] > 1e-9),
        key=lambda e: plan.F[e],
    )
    for x, y in links:
        t_obs = 0.0 if (x, y) == (a, b) else 59.0
        bel.observe(x, y, float(bel.mean[x, y]), weight=8.0, t_s=t_obs)
    pol = make_policy("evoi")
    ctx = PolicyContext(belief=bel, t_s=60.0, planner=pl,
                        contexts=((SRC, DST),), plans=(plan,))
    order = pol.rank(list(links), ctx)
    top3 = [links[int(i)] for i in order[:3]]
    assert (a, b) in top3, (top3, (a, b))


def test_greedy_policy_matches_legacy_scoring(top):
    """The extracted GreedyVoIPolicy must rank exactly as the Calibrator's
    original argsort(-score) did."""
    bel = BeliefGrid(top)
    pl = Planner(top, max_relays=6)
    plan = pl.plan_cost_min(SRC, DST, 3.0, 4.0)
    cal = Calibrator(bel)  # default policy IS greedy
    links = cal.candidate_links(pl, [(SRC, DST)])
    scores = cal.score_links(links, plans=[plan], t_s=5.0)
    ctx = PolicyContext(belief=bel, t_s=5.0, plans=(plan,))
    order = cal.policy.rank(links, ctx)
    assert np.array_equal(order, np.argsort(-scores, kind="stable"))


# ------------------------------------------------------ per-provider priors
def test_default_prior_comes_from_provider_table(top):
    bel = BeliefGrid(top)
    grid = prior_rel_sigma_grid(top)
    assert np.array_equal(bel.prior_rel_sigma, grid)
    providers = [r.provider for r in top.regions]
    i_aws = providers.index("aws")
    i_gcp = providers.index("gcp")
    assert grid[i_aws, i_gcp] == PROVIDER_DRIFT_PRIOR[("aws", "gcp")]
    assert grid[i_gcp, i_gcp] == PROVIDER_DRIFT_PRIOR[("gcp", "gcp")]
    # unknown providers (toy grids) fall back to the old global knob
    toy = toy_topology(n=4, seed=0)
    assert (prior_rel_sigma_grid(toy) == DEFAULT_DRIFT_PRIOR).all()


def test_provider_priors_scale_lcbs_only_for_intended_pairs(top):
    """Acceptance: a per-provider prior moves the LCB exactly on that
    provider pair's links and nowhere else."""
    providers = np.array([r.provider for r in top.regions])
    custom = np.full((top.num_regions, top.num_regions), DEFAULT_DRIFT_PRIOR)
    gcp = providers == "gcp"
    gg = np.outer(gcp, gcp)
    custom[gg] = 0.45
    flat = BeliefGrid(top, prior_rel_sigma=DEFAULT_DRIFT_PRIOR)
    prov = BeliefGrid(top, prior_rel_sigma=custom)
    live = np.asarray(top.tput) > 0
    lb_flat, lb_prov = flat.lower_bound(1.5), prov.lower_bound(1.5)
    assert (lb_prov[gg & live] < lb_flat[gg & live]).all()
    assert np.array_equal(lb_prov[~gg], lb_flat[~gg])


def test_prior_rel_sigma_shape_validated(top):
    with pytest.raises(ValueError, match="scalar or"):
        BeliefGrid(top, prior_rel_sigma=np.ones(3))


def test_reset_link_reseeds_at_per_link_prior(top):
    s, d = top.index(SRC), top.index(DST)
    bel = BeliefGrid(top)
    bel.reset_link(s, d, 1.0)
    sig = bel.prior_rel_sigma[s, d]
    assert bel.sigma()[s, d] == pytest.approx(sig * 1.0)


# -------------------------------------------------------------- epoch rolls
def _degraded_belief(top, s, factor):
    bel = BeliefGrid(top)
    for b in range(top.num_regions):
        if b != s and top.tput[s, b] > 0:
            bel.reset_link(s, b, factor * top.tput[s, b])
    return bel


def _roll_service(top, factor, **kw):
    s = top.index(SRC)
    drift = DriftModel(top, seed=0, drift_sigma=0.02, diurnal_amp=0.0)
    svc = CalibratedTransferService(
        drift, belief=_degraded_belief(top, s, factor), backend="jax",
        max_relays=6, check_interval_s=4.0, policy="round_robin",
        max_segments=120, **kw,
    )
    svc._epoch0 = svc.top  # the construction-time epoch, for assertions
    svc.submit(TransferRequest("roll", SRC, DST, 4.0, 4.0))
    return svc, svc.run()


def test_epoch_roll_fires_and_is_bounded(top):
    """Acceptance: the epoch grid undersells reality 20x; probes raise the
    belief past the hysteresis threshold, the service rolls (counted,
    bounded structure builds), plans re-pin on the improved grid, and
    drift re-plans stay zero-build."""
    svc, rep = _roll_service(top, 0.05, max_epoch_rolls=2)
    assert rep.jobs[0].status == "done"
    assert 1 <= len(rep.epoch_rolls) <= 2
    roll = rep.epoch_rolls[0]
    assert roll.ratio >= svc.epoch_roll_threshold
    assert 0 < rep.epoch_roll_builds <= 8
    # the roll's re-plans live on the roll record, NOT in job replans —
    # every drift re-plan must still be a pure cache hit
    assert all(r.structure_builds == 0 for r in rep.replans)
    assert roll.replans and all(
        r.plan.solver_status == "optimal" for r in roll.replans
    )
    # the epoch was re-pinned: new topology (fresh caches), planner follows,
    # and on the plan-carrying source edges the new epoch sits far above
    # the degraded construction-time grid
    assert svc.top is not svc._epoch0
    assert svc.planner.top is svc.top
    s = top.index(SRC)
    old, new = np.asarray(svc._epoch0.tput), np.asarray(svc.top.tput)
    assert (new[s][old[s] > 0] > old[s][old[s] > 0]).any()


def test_epoch_roll_never_fires_mid_segment(top):
    _, rep = _roll_service(top, 0.05, max_epoch_rolls=2)
    assert rep.epoch_rolls and rep.boundaries
    for roll in rep.epoch_rolls:
        assert any(abs(roll.t_s - b) < 1e-9 for b in rep.boundaries), (
            roll.t_s, rep.boundaries,
        )


def test_epoch_roll_respects_hysteresis_threshold(top):
    """A belief only mildly below reality (ratio < threshold) must NOT
    trigger a roll; the same scenario with a lower threshold must."""
    _, calm = _roll_service(top, 0.95, max_epoch_rolls=2)
    assert calm.epoch_rolls == []
    _, eager = _roll_service(top, 0.95, max_epoch_rolls=2,
                             epoch_roll_threshold=1.01)
    assert eager.epoch_rolls
    _, capped = _roll_service(top, 0.05, max_epoch_rolls=0)
    assert capped.epoch_rolls == []


def test_epoch_roll_improves_delivered_throughput(top):
    _, rolled = _roll_service(top, 0.05, max_epoch_rolls=2)
    _, stale = _roll_service(top, 0.05, max_epoch_rolls=0)
    ach = lambda rep: (  # noqa: E731
        rep.jobs[0].delivered_gb * 8.0 / max(rep.time_s, 1e-9)
    )
    assert ach(rolled) > ach(stale)


# ------------------------------------------------- multicast gateway feed
def test_multicast_gateway_reports_link_rates_and_feeds_belief():
    """The fan-out gateway exposes per-edge bytes/seconds like the unicast
    path; the belief consumes the observed rates."""
    from repro.transfer import BlobStore, transfer_objects_multicast

    top = toy_topology(n=6, seed=3)
    pl = Planner(top, max_relays=4)
    plan = pl.plan_multicast_cost_min("toy:r0", ["toy:r1", "toy:r2"],
                                      1.0, 0.02)
    rng = np.random.default_rng(0)
    src = BlobStore()
    src.put("obj", rng.bytes(1_200_000))
    dsts = {"toy:r1": BlobStore(), "toy:r2": BlobStore()}
    rep = transfer_objects_multicast(plan, src, dsts, ["obj"],
                                     chunk_bytes=1 << 17, workers_per_hop=2)
    assert rep.chunks_missing == 0
    assert rep.per_edge_bytes and rep.per_edge_seconds
    # envelope accounting: every byte crossing any hop is counted once
    assert sum(rep.per_edge_bytes.values()) == rep.bytes_moved
    tree_edges = {e for t in plan.trees() for e in t.edges()}
    assert set(rep.per_edge_bytes) <= tree_edges
    rates = rep.link_gbps()
    assert rates and all(g > 0 for g in rates.values())
    bel = BeliefGrid(top)
    n = bel.observe_link_rates(rates, weight=1.0, t_s=3.0, one_sided=False)
    assert n == len(rates)
    for a, b in rates:
        assert bel.last_obs_t[a, b] == 3.0
