"""TransferService: multi-job admission, fault-driven re-planning on the
degraded topology via cached-structure refits."""

import numpy as np
import pytest

from repro.core import default_topology
from repro.transfer import (
    LinkDegrade,
    TransferRequest,
    TransferService,
    VMFailure,
)
from repro.transfer.flowsim_ref import simulate_multi_reference

SRC, DST = "aws:us-west-2", "aws:eu-central-1"


@pytest.fixture(scope="module")
def top():
    return default_topology()


def _service(top, **kw):
    svc = TransferService(top, backend="jax", max_relays=6, **kw)
    svc.submit(TransferRequest("a", SRC, DST, 3.0, 4.0))
    svc.submit(TransferRequest("b", SRC, DST, 3.0, 4.0, arrival_s=1.0))
    svc.submit(TransferRequest("c", "gcp:us-central1", DST, 3.0, 4.0))
    return svc


def test_service_runs_queue_to_completion(top):
    rep = _service(top).run()
    assert rep.all_done
    assert rep.segments == 1 and not rep.replans
    for j in rep.jobs:
        assert j.delivered_gb == pytest.approx(j.request.volume_gb, rel=0.02)
        assert j.realized_cost > 0
        assert 0.1 < j.tput_ratio <= 1.05


def test_service_replans_on_link_degrade_with_cached_structure(top):
    """Acceptance: re-planning a degraded topology reuses the cached
    LPStructure — zero re-assemblies during the re-plan — and the
    re-planned remainder is feasible and respects the degraded link."""
    svc = _service(top)
    s, d = top.index(SRC), top.index(DST)
    rep = svc.run(faults=[LinkDegrade(t_s=3.0, src=s, dst=d, factor=0.3)])
    assert rep.replans, "jobs on the degraded link must be re-planned"
    for r in rep.replans:
        # milp.N_STRUCT_BUILDS was snapshotted around the re-plan: zero
        # LPStructure assemblies means every constrained solve rode on the
        # structures cached at admission time.
        assert r.structure_builds == 0, "re-plan re-assembled an LPStructure"
        assert r.reused_structure
        plan = r.plan
        assert plan.solver_status == "optimal"
        assert plan.validate() == []  # cost-feasible on the base constraints
        # ... and on the degraded 4b row of the dead link:
        phi = svc.degraded_links[(s, d)]
        cap = phi * top.tput[s, d] * plan.M[s, d] / top.limit_conn
        assert plan.F[s, d] <= cap + 1e-6
        assert np.isfinite(plan.total_cost)
        assert r.latency_s < 5.0
    assert rep.all_done


def test_service_replans_vm_failure_and_survives(top):
    svc = _service(top)
    s = top.index(SRC)
    rep = svc.run(faults=[VMFailure(t_s=2.0, job=0, region=s, count=1)])
    (ra,) = [j for j in rep.jobs if j.request.name == "a"]
    assert ra.replans, "the failed job must be re-planned"
    new_plan = ra.replans[-1].plan
    # the unhealthy region can host at most limit_vm - 1 replacement VMs
    assert new_plan.N[s] <= top.limit_vm - 1 + 1e-9
    assert rep.all_done
    assert ra.delivered_gb == pytest.approx(ra.request.volume_gb, rel=0.02)


def test_vm_failure_is_scoped_to_the_failed_tenant(top):
    """Job 0 losing every VM in the source region must not constrain job
    1's re-plan: VM quota is per tenant, only link health is shared."""
    svc = _service(top)
    s, d = top.index(SRC), top.index(DST)
    rep = svc.run(faults=[
        VMFailure(t_s=2.0, job=0, region=s, count=top.limit_vm),
        LinkDegrade(t_s=3.0, src=s, dst=d, factor=0.5),
    ])
    (rb,) = [j for j in rep.jobs if j.request.name == "b"]
    assert rb.status == "done"
    assert rb.replans, "job b shares the degraded link and must re-plan"
    # job b's re-plan may still provision freely in the source region
    assert rb.replans[-1].plan.solver_status == "optimal"
    assert svc.vm_caps_by_job.get(1) is None


def test_fault_after_completion_does_not_inflate_makespan(top):
    """A scripted fault long after every job finished must not drag the
    reported makespan out to the fault time."""
    svc = _service(top)
    s, d = top.index(SRC), top.index(DST)
    rep = svc.run(faults=[LinkDegrade(t_s=500.0, src=s, dst=d, factor=0.5)])
    assert rep.all_done and not rep.replans
    assert rep.time_s < 400.0


def test_service_reports_realized_vs_planned(top):
    rep = _service(top).run()
    for j in rep.jobs:
        assert j.planned_cost > 0 and j.planned_tput_gbps > 0
        assert j.cost_ratio == pytest.approx(
            j.realized_cost / j.planned_cost, rel=1e-9
        )
        assert j.tput_ratio == pytest.approx(
            j.realized_tput_gbps / j.planned_tput_gbps, rel=1e-9
        )


def test_admission_after_faults_plans_on_degraded_view(top):
    """A job submitted to a service that already carries degraded links is
    planned (and its predictions priced) against that view — it routes
    around the dead link instead of limping through it mispredicted."""
    svc = TransferService(top, backend="jax", max_relays=6)
    svc.submit(TransferRequest("first", SRC, DST, 2.0, 4.0))
    s, d = top.index(SRC), top.index(DST)
    svc.run(faults=[LinkDegrade(t_s=1.0, src=s, dst=d, factor=0.05)])
    assert svc.degraded_links  # the degraded view persists across runs
    svc.submit(TransferRequest("late", SRC, DST, 2.0, 4.0))
    rep = svc.run()
    (late,) = [j for j in rep.jobs if j.request.name == "late"]
    assert late.status == "done"
    plan = late.plan
    # the admission plan respects the degraded 4b row of the dead link
    phi = svc.degraded_links[(s, d)]
    assert plan.F[s, d] <= phi * top.tput[s, d] * plan.M[s, d] / top.limit_conn + 1e-6


def test_service_on_reference_simulator(top):
    """The orchestrator is simulator-agnostic: running the segment sims on
    the object-per-connection oracle gives the same delivered volumes."""
    s, d = top.index(SRC), top.index(DST)
    faults = [LinkDegrade(t_s=3.0, src=s, dst=d, factor=0.5)]
    fast = _service(top).run(faults=faults)
    slow = _service(top).run(faults=faults, sim=simulate_multi_reference)
    assert [j.delivered_gb for j in fast.jobs] == pytest.approx(
        [j.delivered_gb for j in slow.jobs]
    )
    assert [j.status for j in fast.jobs] == [j.status for j in slow.jobs]
