"""Sharding-rule resolution and elastic rescale planning."""

import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import default_topology
from repro.launch.elastic import plan_reshard
from repro.sharding.specs import ShardingRules, logical_to_physical


class _FakeMesh:
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


def test_nondivisible_dims_fall_back_to_replication():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(batch=("data",), fsdp="data", tp="model")
    # 28 heads don't divide 16 -> replicated; 1184-wide ff does -> sharded
    spec = logical_to_physical(rules, ("fsdp", "tp", None), (3584, 28, 128), mesh)
    assert spec[0] == "data" and spec[1] is None
    spec = logical_to_physical(rules, ("fsdp", "tp"), (3584, 18944), mesh)
    assert spec[1] == "model"


def test_axis_never_used_twice():
    mesh = _FakeMesh({"data": 4, "model": 4})
    rules = ShardingRules(batch=("data",), fsdp="data", tp="model")
    spec = logical_to_physical(rules, ("fsdp", "fsdp"), (64, 64), mesh)
    assert spec[0] == "data" and spec[1] is None


def test_rules_filter_for_single_pod_mesh():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(batch=("pod", "data"), fsdp="data", tp="model")
    f = rules.filter_for_mesh(mesh)
    assert f.batch == ("data",) or f.batch == "data"


def test_reshard_plan_prices_pod_join():
    cfg = reduced(get_arch("qwen2-7b"))
    top = default_topology()
    old = ["aws:us-west-2", "gcp:us-central1"]
    new = old + ["azure:westeurope"]
    plan = plan_reshard(cfg, top, old, new, tput_floor_gbps=5.0)
    assert plan.new_pods == 3 and len(plan.moves) == 1
    src, dst, gb, tput, cost = plan.moves[0]
    assert dst == "azure:westeurope" and src in old
    assert gb == pytest.approx(cfg.param_count() * 12 / 1e9, rel=1e-6)
    assert cost > 0 and tput > 0


def test_reshard_noop_on_shrink():
    cfg = reduced(get_arch("smollm-135m"))
    top = default_topology()
    old = ["aws:us-west-2", "gcp:us-central1"]
    plan = plan_reshard(cfg, top, old, old[:1])
    assert plan.moves == [] and plan.total_cost == 0.0
