"""Three-way sim-engine parity: ref / soa / jax behind transfer.sim.simulate.

The dispatcher contract (ISSUE 10) is that every engine consumes the same
materialized scenario and produces the same answer. The pins are graded by
what the engines actually share:

  * soa vs jax — BITWISE equality of every ``JobSimResult`` field, the
    event count and the wall of the run. The jax engine replays the SoA
    semantics on fixed-shape padded arrays (chunk counts are nowhere near
    the 128-lane pad, so every scenario here exercises the validity
    masks); a single ulp of drift anywhere fails these tests.
  * ref vs soa — semantic equality: statuses, chunk counts, retries,
    per-destination deliveries, times and event counts are exact; costs
    and per-edge GB go through a different accumulation order in the
    object-per-connection oracle, so they are pinned to float tolerance;
    ``per_edge_active_s``/``per_edge_obs_gb`` are vectorized-only
    telemetry (documented on ``JobSimResult``) and excluded.
  * Skytrace — the emitted streams must be identical tuples across all
    three engines: the observability plane cannot depend on the engine.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import Planner, PlanSpec, default_topology, direct_plan, milp
from repro.obs import trace
from repro.transfer import (
    GrayFailure,
    LinkDegrade,
    LinkRestore,
    TransferJob,
    VMFailure,
    simulate,
)
from repro.transfer.events import materialize_jobs
from repro.transfer.simconfig import ENGINE_NAMES

SRC, DST = "aws:us-west-2", "aws:eu-central-1"
SRC2 = "gcp:us-central1"
MC_SRC = "gcp:us-central1"
MC_DSTS = ("gcp:europe-west1", "gcp:europe-west3", "gcp:europe-west4")

# ref-vs-soa float-tolerance fields (different accumulation order) and the
# vectorized-only telemetry fields.
_COST_FIELDS = ("egress_cost", "vm_cost", "total_cost", "tput_gbps")
_TELEMETRY = ("per_edge_active_s", "per_edge_obs_gb")


@pytest.fixture(scope="module")
def top():
    return default_topology()


def _unicast_jobs(top, volume=0.5):
    return [
        TransferJob(direct_plan(top, SRC, DST, volume, num_vms=2), "a"),
        TransferJob(direct_plan(top, SRC, DST, volume, num_vms=2), "b",
                    arrival_s=1.0),
        TransferJob(direct_plan(top, SRC2, DST, volume, num_vms=2), "c"),
    ]


def run_engines(jobs, faults=(), **kw):
    """Run the scenario on every registered engine, capturing Skytrace."""
    out, traces = {}, {}
    for eng in ENGINE_NAMES:
        tr = trace.enable(capacity=1 << 16)
        try:
            out[eng] = simulate(jobs, faults, engine=eng, **kw)
            traces[eng] = tr.events()
        finally:
            trace.disable()
    return out, traces


def assert_parity(out, traces):
    ref, soa, jx = out["ref"], out["soa"], out["jax"]

    # soa vs jax: bitwise, every field
    assert jx.time_s == soa.time_s
    assert jx.events == soa.events
    for a, b in zip(jx.jobs, soa.jobs):
        assert dataclasses.asdict(a) == dataclasses.asdict(b)

    # ref vs soa: exact on discrete outcomes and times, tolerant on the
    # differently-accumulated money/byte sums
    assert ref.time_s == soa.time_s
    assert ref.events == soa.events
    for a, b in zip(ref.jobs, soa.jobs):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        for k in _COST_FIELDS:
            assert da.pop(k) == pytest.approx(db.pop(k), rel=1e-9)
        for k in _TELEMETRY:
            da.pop(k), db.pop(k)
        ega, egb = da.pop("per_edge_gb"), db.pop("per_edge_gb")
        assert set(ega) == set(egb)
        for e in ega:
            assert ega[e] == pytest.approx(egb[e], rel=1e-9)
        assert da == db

    # the Skytrace stream is engine-independent, tuple for tuple
    assert traces["soa"] == traces["ref"]
    assert traces["jax"] == traces["ref"]


def test_plain_three_jobs(top):
    out, traces = run_engines(_unicast_jobs(top), seed=0)
    assert_parity(out, traces)
    assert all(j.status == "done" for j in out["jax"].jobs)


def test_every_rate_event_and_vm_failure(top):
    """One scripted instance of EVERY events.py event class (the full
    RATE_EVENTS group plus VMFailure) against delayed arrivals."""
    s, d, s2 = top.index(SRC), top.index(DST), top.index(SRC2)
    faults = [
        LinkDegrade(t_s=0.5, src=s, dst=d, factor=0.5),
        GrayFailure(t_s=0.8, src=s2, dst=d, factor=0.4),
        VMFailure(t_s=1.0, job=0, region=s, count=1),
        LinkRestore(t_s=1.4, src=s, dst=d, factor=2.0),
        GrayFailure(t_s=1.6, src=s2, dst=d, factor=2.5),
    ]
    out, traces = run_engines(_unicast_jobs(top), faults, seed=0)
    assert_parity(out, traces)
    assert sum(j.retried_chunks for j in out["jax"].jobs) > 0, (
        "the VM failure must actually force retries for this scenario to "
        "exercise the requeue path"
    )


def test_horizon_cut_and_drain(top):
    jobs = _unicast_jobs(top)
    s, d = top.index(SRC), top.index(DST)
    faults = [LinkDegrade(t_s=0.4, src=s, dst=d, factor=0.3)]
    hard, hard_tr = run_engines(jobs, faults, seed=0, horizon_s=1.0)
    assert_parity(hard, hard_tr)
    assert any(j.status == "running" for j in hard["jax"].jobs), (
        "horizon must cut mid-transfer or the scenario tests nothing"
    )
    assert hard["jax"].time_s <= 1.0 + 1e-9

    soft, soft_tr = run_engines(jobs, faults, seed=0, horizon_s=1.0,
                                drain=True)
    assert_parity(soft, soft_tr)
    assert soft["jax"].time_s >= hard["jax"].time_s


def test_link_contention_disabled(top):
    out, traces = run_engines(
        _unicast_jobs(top), seed=0, link_capacity_scale=None,
    )
    assert_parity(out, traces)


def test_multicast_and_unicast_mix(top):
    planner = Planner(top, max_relays=6)
    mc = planner.plan(PlanSpec(
        objective="cost_min", src=MC_SRC, dsts=MC_DSTS,
        tput_goal_gbps=2.0, volume_gb=1.0,
    ))
    assert mc.solver_status == "optimal"
    jobs = [
        TransferJob(mc, "repl"),
        TransferJob(direct_plan(top, SRC, DST, 0.5, num_vms=2), "uni",
                    arrival_s=0.5),
    ]
    kill = next(int(r) for r in mc.dsts if mc.N[r] >= 1)
    faults = [VMFailure(t_s=0.8, job=0, region=kill, count=1)]
    out, traces = run_engines(jobs, faults, seed=0)
    assert_parity(out, traces)
    repl = out["jax"].jobs[0]
    assert repl.per_dst_delivered is not None
    assert set(repl.per_dst_delivered) == {int(r) for r in mc.dsts}


def test_engines_do_not_rebuild_lp_structures(top):
    """Simulation is planning-free: no engine may touch the LP structure
    cache (the planner hot path the fleet PRs pinned)."""
    jobs = _unicast_jobs(top)
    builds0 = milp.N_STRUCT_BUILDS
    run_engines(jobs, seed=0)
    assert milp.N_STRUCT_BUILDS == builds0


def test_tied_arrivals_order_is_deterministic(top):
    """Jobs arriving at the exact same instant materialize in submission
    order — ``MultiSetup.arrival_order`` is the (arrival, index) sort every
    engine consumes, so ties cannot reorder across runs or engines."""
    jobs = [
        TransferJob(direct_plan(top, SRC, DST, 0.25, num_vms=2), "x",
                    arrival_s=1.0),
        TransferJob(direct_plan(top, SRC2, DST, 0.25, num_vms=2), "y",
                    arrival_s=1.0),
        TransferJob(direct_plan(top, SRC, DST, 0.25, num_vms=2), "z"),
    ]
    orders = [
        materialize_jobs(jobs, seed=0).arrival_order for _ in range(2)
    ]
    assert np.array_equal(orders[0], orders[1])
    assert orders[0].tolist() == [2, 0, 1], (
        "equal arrivals must keep submission order"
    )
    out, traces = run_engines(jobs, seed=0)
    assert_parity(out, traces)
