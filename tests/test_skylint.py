"""Self-tests for the skylint static-analysis engine (ISSUE 8).

Each rule gets a good/bad fixture pair built as a synthetic mini-tree under
``tmp_path`` (rules key off root-relative paths, so the trees mirror the
real layout). The meta-tests at the bottom pin the active-rule id set and
run the checker over the LIVE repo — the blocking CI gate can never
silently rot: deleting a rule breaks the id pin, a regression anywhere in
the tree breaks the exit-0 pin.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import active_rule_ids, check

REPO_ROOT = Path(__file__).resolve().parents[1]

EXPECTED_RULES = (
    "SKY001", "SKY002", "SKY003", "SKY004",
    "SKY005", "SKY006", "SKY007", "SKY008", "SKY009", "SKY010",
)


def lint(tmp_path, files):
    """Write the fixture tree and run the full rule set over it."""
    roots = set()
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src), encoding="utf-8")
        roots.add(rel.split("/")[0])
    return check(tmp_path, sorted(roots))


def rule_ids(report):
    return [f.rule for f in report.findings]


# A minimal parity-clean engine trio every SKY004 fixture starts from.
EVENTS_SRC = """\
    import dataclasses


    @dataclasses.dataclass(frozen=True)
    class LinkDegrade:
        t_s: float
        factor: float


    @dataclasses.dataclass(frozen=True)
    class VMFailure:
        t_s: float
        job: int


    RATE_EVENTS = (LinkDegrade,)
"""

SIM_BODY = """\
        for ev in faults:
            if isinstance(ev, int):
                pass
            elif isinstance(ev, RATE_EVENTS):
                pass
            elif isinstance(ev, VMFailure):
                pass
"""

FLOWSIM_SRC = (
    "    def simulate_multi(jobs, faults=(), *, seed=0):\n" + SIM_BODY
)
FLOWSIM_REF_SRC = (
    "    def simulate_multi_reference(jobs, faults=(), *, seed=0):\n"
    + SIM_BODY
)
# The jax engine applies events in a host helper, not the entry point —
# dispatch coverage is checked module-wide, so this must lint clean.
FLOWSIM_JAX_SRC = (
    "    def _host_apply_due(faults):\n" + SIM_BODY + "\n\n"
    "    def simulate_multi_jax(jobs, faults=(), *, seed=0, "
    '_rate_solver="auto"):\n'
    "        _host_apply_due(faults)\n"
)
SIM_SRC = """\
    def simulate(jobs, faults=(), *, seed=0, engine="soa"):
        if engine == "soa":
            pass
"""


def parity_tree(flowsim=FLOWSIM_SRC, ref=FLOWSIM_REF_SRC,
                jax=FLOWSIM_JAX_SRC, sim=SIM_SRC):
    return {
        "src/repro/transfer/events.py": EVENTS_SRC,
        "src/repro/transfer/flowsim.py": flowsim,
        "src/repro/transfer/flowsim_ref.py": ref,
        "src/repro/transfer/flowsim_jax.py": jax,
        "src/repro/transfer/sim.py": sim,
    }


# ------------------------------------------------------------------- SKY001
def test_sky001_fires_on_unseeded_and_global_rng(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        import random

        import numpy as np

        rng = np.random.default_rng()
        v = np.random.rand(3)
        r = random.random()
    """})
    assert rule_ids(rep) == ["SKY001", "SKY001", "SKY001"]


def test_sky001_fires_on_wall_clock_in_sim_code(tmp_path):
    rep = lint(tmp_path, {"src/repro/calibrate/x.py": """\
        import time

        t0 = time.time()
    """})
    assert rule_ids(rep) == ["SKY001"]


def test_sky001_allows_seeded_rng_monotonic_and_bench_clocks(tmp_path):
    rep = lint(tmp_path, {
        "src/repro/core/x.py": """\
            import random
            import time

            import numpy as np

            rng = np.random.default_rng(0)
            r = random.Random(7)
            t0 = time.monotonic()
            t1 = time.perf_counter()
        """,
        # wall-clock is fine OUTSIDE the deterministic sim/planner dirs
        "benchmarks/x.py": """\
            import time

            t0 = time.time()
        """,
    })
    assert rep.ok, rep.to_text()


# ------------------------------------------------------------------- SKY002
def test_sky002_fires_outside_milp_and_allows_milp_itself(tmp_path):
    rep = lint(tmp_path, {
        "src/repro/transfer/x.py": """\
            s = LPStructure(top, 0, 1)
            m = MulticastLPStructure(top, 0, (1, 2))
        """,
        "src/repro/core/milp.py": """\
            def structure(top, src, dst):
                return LPStructure(top, src, dst)
        """,
    })
    assert rule_ids(rep) == ["SKY002", "SKY002"]
    assert all(f.path == "src/repro/transfer/x.py" for f in rep.findings)


# ------------------------------------------------------------------- SKY003
def test_sky003_fires_on_grid_subscript_stores(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        top.tput[0, 1] = 5.0
        top.price_egress[2, 3] *= 0.5
    """})
    assert rule_ids(rep) == ["SKY003", "SKY003"]


def test_sky003_allows_with_tput_and_plain_arrays(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        arr[0] = 5.0
        top2 = top.with_tput(scale=0.5)
        rate = top.tput[0, 1]
    """})
    assert rep.ok, rep.to_text()


# ------------------------------------------------------------------- SKY004
def test_sky004_clean_on_matching_sims(tmp_path):
    rep = lint(tmp_path, parity_tree())
    assert rep.ok, rep.to_text()


def test_sky004_fires_on_signature_drift(tmp_path):
    drifted = (
        "    def simulate_multi_reference(jobs, faults=(), *, seed=0, "
        "extra=None):\n" + SIM_BODY
    )
    rep = lint(tmp_path, parity_tree(ref=drifted))
    assert rule_ids(rep) == ["SKY004"]
    assert "signatures" in rep.findings[0].message


def test_sky004_fires_on_missing_dispatch_branch(tmp_path):
    ref_no_vmfail = (
        "    def simulate_multi_reference(jobs, faults=(), *, seed=0):\n"
        "        for ev in faults:\n"
        "            if isinstance(ev, int):\n"
        "                pass\n"
        "            elif isinstance(ev, RATE_EVENTS):\n"
        "                pass\n"
    )
    rep = lint(tmp_path, parity_tree(ref=ref_no_vmfail))
    assert rule_ids(rep) == ["SKY004"]
    assert "VMFailure" in rep.findings[0].message
    assert "flowsim_ref" in rep.findings[0].message


def test_sky004_jax_dispatch_is_checked_module_wide(tmp_path):
    # entry point + helper with no VMFailure branch anywhere in the module
    jax_no_vmfail = (
        "    def _host_apply_due(faults):\n"
        "        for ev in faults:\n"
        "            if isinstance(ev, int):\n"
        "                pass\n"
        "            elif isinstance(ev, RATE_EVENTS):\n"
        "                pass\n\n\n"
        "    def simulate_multi_jax(jobs, faults=(), *, seed=0, "
        '_rate_solver="auto"):\n'
        "        _host_apply_due(faults)\n"
    )
    rep = lint(tmp_path, parity_tree(jax=jax_no_vmfail))
    assert rule_ids(rep) == ["SKY004"]
    assert "VMFailure" in rep.findings[0].message
    assert "flowsim_jax" in rep.findings[0].message


def test_sky004_fires_on_public_jax_knob(tmp_path):
    jax_public = (
        "    def simulate_multi_jax(jobs, faults=(), *, seed=0, "
        'solver="auto"):\n' + SIM_BODY
    )
    rep = lint(tmp_path, parity_tree(jax=jax_public))
    assert rule_ids(rep) == ["SKY004"]
    assert "private" in rep.findings[0].message


def test_sky004_fires_on_dispatcher_drift(tmp_path):
    # engine must be the TRAILING knob with default "soa"
    drifted = """\
        def simulate(jobs, faults=(), *, engine="soa", seed=0):
            pass
    """
    rep = lint(tmp_path, parity_tree(sim=drifted))
    assert rule_ids(rep) == ["SKY004"]
    assert "sim.simulate" in rep.findings[0].message


def test_sky004_fires_when_an_engine_file_is_missing(tmp_path):
    tree = parity_tree()
    del tree["src/repro/transfer/sim.py"]
    rep = lint(tmp_path, tree)
    assert rule_ids(rep) == ["SKY004"]
    assert "sim.py" in rep.findings[0].message


# ------------------------------------------------------------------- SKY005
def test_sky005_fires_on_protocol_gaps(tmp_path):
    rep = lint(tmp_path, {"src/repro/transfer/x.py": """\
        import dataclasses


        @dataclasses.dataclass
        class FooReport:
            value: float
    """})
    assert rule_ids(rep) == ["SKY005"]
    msg = rep.findings[0].message
    assert "kind" in msg and "to_dict" in msg and "summary" in msg


def test_sky005_accepts_conformant_and_inherited_reports(tmp_path):
    rep = lint(tmp_path, {
        "src/repro/transfer/reports.py": """\
            class Report:
                kind = "report"

                def _payload(self):
                    raise NotImplementedError

                def to_dict(self):
                    return {"kind": self.kind, **self._payload()}

                def summary(self):
                    return self.kind
        """,
        "src/repro/transfer/x.py": """\
            from .reports import Report


            class FooReport(Report):
                kind = "foo"

                def _payload(self):
                    return {}


            class SubFooReport(FooReport):
                kind = "subfoo"
        """,
    })
    assert rep.ok, rep.to_text()


# ------------------------------------------------------------------- SKY006
def test_sky006_fires_in_first_party_code_not_tests(tmp_path):
    shim_call = """\
        def run(planner):
            return planner.plan_cost_min("a", "b", 1.0, 2.0)
    """
    rep = lint(tmp_path, {
        "benchmarks/x.py": shim_call,
        "tests/test_x.py": shim_call,  # tests pin shim equality: exempt
    })
    assert rule_ids(rep) == ["SKY006"]
    assert rep.findings[0].path == "benchmarks/x.py"


# ------------------------------------------------------------------- SKY010
def test_sky010_fires_on_direct_engine_entry_calls(tmp_path):
    rep = lint(tmp_path, {"src/repro/calibrate/x.py": """\
        from repro.transfer.flowsim import simulate_multi


        def go(jobs, flowsim_ref):
            a = simulate_multi(jobs)
            b = flowsim_ref.simulate_multi_reference(jobs)
            return a, b
    """})
    assert rule_ids(rep) == ["SKY010", "SKY010"]
    assert "dispatcher" in rep.findings[0].message


def test_sky010_exempts_tests_and_engine_homes(tmp_path):
    rep = lint(tmp_path, {
        # tests pin shim equality: exempt
        "tests/test_x.py": "r = simulate_multi([])\n",
        # the dispatcher itself calls the impls: exempt
        "src/repro/transfer/sim.py": """\
            def simulate(jobs, faults=(), *, seed=0, engine="soa"):
                return _simulate_multi_impl(jobs, faults)
        """,
    })
    assert rep.ok, rep.to_text()


# ------------------------------------------------------------------- SKY007
def test_sky007_fires_on_unregistered_module_state(tmp_path):
    rep = lint(tmp_path, {"src/repro/transfer/x.py": """\
        CACHE = {}
        __all__ = ["run"]
    """})
    assert rule_ids(rep) == ["SKY007"]
    assert "CACHE" in rep.findings[0].message


def test_sky009_fires_on_rogue_global(tmp_path):
    rep = lint(tmp_path, {"src/repro/calibrate/x.py": """\
        def bump():
            global COUNT
            COUNT = 1
    """})
    assert rule_ids(rep) == ["SKY009"]
    assert "COUNT" in rep.findings[0].message


def test_sky009_fires_on_zero_seeded_module_counter(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        N_CALLS = 0
    """})
    assert rule_ids(rep) == ["SKY009"]
    assert "N_CALLS" in rep.findings[0].message


def test_sky009_allows_constants_and_registry_instruments(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        from repro.obs.metrics import REGISTRY

        MAX_RELAYS = 10
        T_FLOOR = 0.5
        _calls = REGISTRY.counter("core.calls")
    """})
    assert rep.ok, rep.to_text()


def test_sky007_worker_closure_needs_the_lock(tmp_path):
    unlocked = """\
        import threading


        def run():
            shared = {}
            lock = threading.Lock()

            def worker():
                shared["k"] = 1

            threading.Thread(target=worker).start()
    """
    rep = lint(tmp_path, {"src/repro/transfer/gateway.py": unlocked})
    assert rule_ids(rep) == ["SKY007"]
    assert "worker" in rep.findings[0].message

    locked = unlocked.replace(
        '    shared["k"] = 1',
        '    with lock:\n                    shared["k"] = 1',
    )
    rep = lint(tmp_path, {"src/repro/transfer/gateway.py": locked})
    assert rep.ok, rep.to_text()


# ------------------------------------------------------------------- SKY008
def test_sky008_fires_on_format_drift(tmp_path):
    long_line = "x = " + "1 + " * 30 + "1"
    rep = lint(tmp_path, {
        "src/repro/core/x.py": long_line + "\ny = 'single'\n",
    })
    assert rule_ids(rep) == ["SKY008", "SKY008"]


def test_sky008_allows_quotes_that_ruff_would_keep(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        a = "double"
        b = 'has "embedded" doubles'
    """})
    assert rep.ok, rep.to_text()


# ------------------------------------------------------------------ pragmas
def test_line_pragma_suppresses_that_line_only(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        top.tput[0, 1] = 5.0  # skylint: disable=SKY003
        top.tput[2, 3] = 5.0
    """})
    assert rule_ids(rep) == ["SKY003"]
    assert rep.findings[0].line == 2
    # every pragma is recorded for the allowlist audit
    assert [(p.scope, p.line, p.rules) for p in rep.pragmas] == [
        ("line", 1, ("SKY003",))
    ]


def test_file_pragma_suppresses_whole_file(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        # skylint: disable=SKY003
        top.tput[0, 1] = 5.0
        top.tput[2, 3] = 5.0
    """})
    assert rep.ok, rep.to_text()
    assert rep.pragmas[0].scope == "file"


def test_unknown_pragma_id_is_audited(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        # skylint: disable=SKY999
        x = 1
    """})
    assert rule_ids(rep) == ["SKY000"]
    assert "SKY999" in rep.findings[0].message


def test_pragma_inside_string_literal_is_not_a_pragma(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        note = "# skylint: disable=SKY003"
        top.tput[0, 1] = 5.0
    """})
    assert rule_ids(rep) == ["SKY003"]
    assert rep.pragmas == []


# --------------------------------------------------------------- the report
def test_json_report_schema(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": """\
        top.tput[0, 1] = 5.0  # skylint: disable=SKY008
    """})
    d = json.loads(rep.to_json())
    assert set(d) == {
        "version", "ok", "files_scanned", "rules", "findings", "pragmas",
    }
    assert d["ok"] is False and d["files_scanned"] == 1
    assert [r["id"] for r in d["rules"]] == list(EXPECTED_RULES)
    assert all(
        set(r) == {"id", "severity", "description"} for r in d["rules"]
    )
    (f,) = d["findings"]
    assert set(f) == {"path", "line", "rule", "severity", "message", "hint"}
    assert f["rule"] == "SKY003" and f["line"] == 1
    (p,) = d["pragmas"]
    assert p == {
        "path": "src/repro/core/x.py", "line": 1, "scope": "line",
        "rules": ["SKY008"],
    }


def test_syntax_error_is_a_finding_not_a_crash(tmp_path):
    rep = lint(tmp_path, {"src/repro/core/x.py": "def broken(:\n"})
    assert rule_ids(rep) == ["SKY000"]
    assert "syntax error" in rep.findings[0].message


def test_cli_exit_codes_and_json_output(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "clean.py").write_text('X = "ok"\n', encoding="utf-8")
    env_cmd = [
        sys.executable, "-m", "repro.analysis", "check", "src",
        "--root", str(tmp_path), "--format", "json",
    ]
    proc = subprocess.run(
        env_cmd, capture_output=True, text=True, cwd=REPO_ROOT,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr
    assert json.loads(proc.stdout)["ok"] is True

    (tmp_path / "src" / "bad.py").write_text(
        "top.tput[0, 1] = 5.0\n", encoding="utf-8"
    )
    proc = subprocess.run(
        env_cmd, capture_output=True, text=True, cwd=REPO_ROOT,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 1
    out = json.loads(proc.stdout)
    assert out["ok"] is False
    assert [f["rule"] for f in out["findings"]] == ["SKY003"]


# ------------------------------------------------------------- meta (gate)
def test_active_rule_set_is_pinned():
    """Deleting (or renaming) a rule must fail CI, not silently narrow the
    gate. New rules extend this tuple deliberately."""
    assert active_rule_ids() == EXPECTED_RULES


def test_live_repo_is_clean():
    """The blocking CI gate, run in-process: skylint over the real tree
    exits clean. Any new violation anywhere in src/tests/benchmarks/
    examples fails here first."""
    rep = check(REPO_ROOT, ["src", "tests", "benchmarks", "examples"])
    assert len(rep.rules) >= 7
    assert rep.ok, "\n" + rep.to_text()
