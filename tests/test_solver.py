"""IPM LP solver + branch & bound vs. oracles (scipy HiGHS is test-only)."""

import numpy as np
import pytest

from repro.core import milp, toy_topology
from repro.core.solver.bnb import solve_milp
from repro.core.solver.ipm import solve_lp

scipy_opt = pytest.importorskip("scipy.optimize")


def _random_lp(rng, n=18, m_ub=10, m_eq=3):
    """Random bounded-feasible LP: min c@x, A_ub x <= b_ub, A_eq x = b_eq."""
    x0 = rng.uniform(0.5, 2.0, n)  # interior feasible point
    A_ub = rng.normal(size=(m_ub, n))
    b_ub = A_ub @ x0 + rng.uniform(0.5, 2.0, m_ub)
    A_eq = rng.normal(size=(m_eq, n))
    b_eq = A_eq @ x0
    c = rng.uniform(0.1, 2.0, n)  # positive costs => bounded below on x>=0
    return c, A_ub, b_ub, A_eq, b_eq


@pytest.mark.parametrize("seed", range(10))
def test_ipm_matches_highs_random(seed):
    rng = np.random.default_rng(seed)
    c, A_ub, b_ub, A_eq, b_eq = _random_lp(rng)
    mine = solve_lp(c, A_ub, b_ub, A_eq, b_eq)
    ref = scipy_opt.linprog(
        c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq, bounds=(0, None),
        method="highs",
    )
    assert mine.ok == ref.success
    if ref.success:
        assert mine.fun == pytest.approx(ref.fun, rel=1e-6, abs=1e-8)


@pytest.mark.parametrize("seed", range(6))
def test_ipm_matches_highs_on_skyplane_lp(seed):
    top = toy_topology(n=6, seed=seed)
    lp = milp.build_lp(top, 0, 1, 3.0)
    mine = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    ref = scipy_opt.linprog(
        lp.c, A_ub=lp.A_ub, b_ub=lp.b_ub, A_eq=lp.A_eq, b_eq=lp.b_eq,
        bounds=(0, None), method="highs",
    )
    assert mine.ok and ref.success
    assert mine.fun == pytest.approx(ref.fun, rel=1e-5)


def test_ipm_detects_infeasible():
    # x >= 0 with x1 + x2 <= -1 is infeasible
    c = np.ones(2)
    A_ub = np.array([[1.0, 1.0]])
    b_ub = np.array([-1.0])
    res = solve_lp(c, A_ub, b_ub, np.zeros((0, 2)), np.zeros(0))
    assert not res.ok


@pytest.mark.parametrize("seed", range(5))
def test_round_down_within_one_percent_of_exact(seed):
    """Paper §5.1.3: relaxation+rounding is <=1% from the exact MILP."""
    top = toy_topology(n=6, seed=seed)
    rel = solve_milp(top, 0, 1, 3.0, mode="relaxed")
    ex = solve_milp(top, 0, 1, 3.0, mode="exact")
    assert rel.ok and ex.ok
    assert rel.objective <= ex.objective * 1.01 + 1e-9
    # exact is a true lower bound above the LP relaxation
    assert ex.objective >= ex.lp_objective - 1e-9


def test_milp_reports_infeasible_goal():
    top = toy_topology(n=5, seed=1)
    res = solve_milp(top, 0, 1, 1e6, mode="relaxed")  # absurd goal
    assert not res.ok
