"""Equivalence pins for the planner fast path.

Three layers, matching the optimization stack:
  * LPStructure's vectorized assembly is bit-identical to the original
    row-loop assembly (build_lp_reference);
  * the batched solvers (numpy stacked-LAPACK engine and the vmapped JAX
    IPM) match the sequential reference IPM on Skyplane LPs, including the
    pinned-variable RHS-shift batches of the round-down pipeline;
  * §5.1.3 (paper): relaxed round-down is within 1% of exact B&B on pruned
    subgraphs, and the batched round-down pipeline returns the sequential
    path's plans.
"""

import numpy as np
import pytest

from repro.core import Planner, default_topology, milp, toy_topology
from repro.core.solver.bnb import solve_milp, solve_milp_batched
from repro.core.solver.ipm import solve_lp
from repro.core.solver.ipm_batch import solve_lp_batched as solve_lp_batched_np
from repro.core.solver.ipm_jax import solve_lp_batched as solve_lp_batched_jax


# ------------------------------------------------------- assembly equivalence
@pytest.mark.parametrize("seed", range(4))
def test_vectorized_assembly_matches_reference(seed):
    top = toy_topology(n=6, seed=seed)
    rng = np.random.default_rng(seed)
    e = len(top.edge_list(0, 1))
    nx = 2 * e + 6
    cut = np.zeros(nx)
    cut[e + 2] = 1.0
    variants = [
        dict(),
        dict(fixed_n=rng.integers(0, 3, 6).astype(float)),
        dict(fixed_n=np.full(6, 2.0),
             fixed_m=rng.integers(0, 5, (6, 6)).astype(float)),
        dict(extra_ub=[(cut, 1.5)]),
        dict(fixed_n=np.full(6, 1.0), extra_ub=[(cut, 0.5)]),
    ]
    for kwargs in variants:
        fast = milp.build_lp(top, 0, 1, 3.0, **kwargs)
        ref = milp.build_lp_reference(top, 0, 1, 3.0, **kwargs)
        for field in ("c", "A_ub", "b_ub", "A_eq", "b_eq", "integer_mask"):
            np.testing.assert_array_equal(
                getattr(fast, field), getattr(ref, field), err_msg=field
            )
        assert fast.trivially_infeasible == ref.trivially_infeasible
        assert (fast.row_4c, fast.row_4d) == (ref.row_4c, ref.row_4d)
        x = rng.uniform(size=fast.c.shape[0])
        for a, b in zip(fast.split(x), ref.split(x)):
            np.testing.assert_array_equal(a, b)


def test_structure_cache_reused():
    top = toy_topology(n=5, seed=0)
    s1 = milp.structure(top, 0, 1)
    s2 = milp.structure(top, 0, 1)
    assert s1 is s2
    assert milp.structure(top, 0, 2) is not s1


# ------------------------------------------------- batched engines vs the IPM
def _goal_batch(top, goals):
    lp = milp.build_lp(top, 0, 1, float(goals[0]))
    b = np.tile(lp.b_ub[None, :], (len(goals), 1))
    b[:, lp.row_4c] = -goals
    b[:, lp.row_4d] = -goals
    return lp, b


@pytest.mark.parametrize("engine", ["numpy", "jax"])
def test_batched_engine_matches_sequential_on_goal_sweep(engine):
    top = toy_topology(n=6, seed=4)
    goals = np.array([0.5, 1.5, 2.5, 3.5])
    lp, b = _goal_batch(top, goals)
    solver = solve_lp_batched_np if engine == "numpy" else solve_lp_batched_jax
    xs, funs, ok = solver(lp.c, lp.A_ub, b, lp.A_eq, lp.b_eq)
    for i, g in enumerate(goals):
        ref = solve_lp(lp.c, lp.A_ub, np.asarray(b[i]), lp.A_eq, lp.b_eq)
        if ref.ok and ok[i]:
            assert funs[i] == pytest.approx(ref.fun, rel=1e-6, abs=1e-8)
        else:
            # engines may certify different borderline samples, but never
            # disagree on a sample both consider solved
            assert not (ok[i] and ref.ok)


@pytest.mark.parametrize("engine", ["numpy", "jax"])
def test_batched_engine_matches_sequential_on_pinned_shifts(engine):
    """The round-down refit batches: same matrices, per-sample RHS shifts
    from pinned N vectors (milp.LPStructure.batch_b_ub)."""
    top = toy_topology(n=6, seed=2)
    struct = milp.structure(top, 0, 1)
    pat = struct.pin_pattern(True, False)
    n_vecs = np.array([
        [2.0, 2.0, 1.0, 1.0, 1.0, 1.0],
        [2.0, 2.0, 0.0, 2.0, 0.0, 1.0],
        [1.0, 1.0, 1.0, 0.0, 0.0, 0.0],
    ])
    b, triv = struct.batch_b_ub(pat, np.full(3, 0.8), n_vecs)
    assert not triv.any()
    solver = solve_lp_batched_np if engine == "numpy" else solve_lp_batched_jax
    xs, funs, ok = solver(
        pat.c_free, pat.A_ub_free, b, pat.A_eq_free, struct.b_eq[pat.keep_eq]
    )
    for i in range(3):
        lp = struct.lp(0.8, fixed_n=n_vecs[i])
        ref = solve_lp(lp.c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
        assert ok[i] == ref.ok
        if ref.ok:
            assert funs[i] == pytest.approx(ref.fun, rel=1e-6, abs=1e-8)


# ------------------------------------------- round-down pipeline equivalence
def test_batched_round_down_matches_sequential_plans():
    top = default_topology()
    planner = Planner(top)
    src, dst = "aws:us-east-1", "gcp:europe-west4"
    fast = planner.pareto_frontier(src, dst, 10.0, n_samples=6, backend="jax")
    slow = planner.pareto_frontier(src, dst, 10.0, n_samples=6)
    assert len(fast) == len(slow)
    for a, b in zip(fast, slow):
        assert a.tput_goal == pytest.approx(b.tput_goal)
        assert a.cost_per_gb == pytest.approx(b.cost_per_gb, abs=1e-6)
        np.testing.assert_array_equal(a.plan.N, b.plan.N)
        np.testing.assert_array_equal(a.plan.M, b.plan.M)


def test_batched_cost_min_matches_sequential():
    top = default_topology()
    planner = Planner(top)
    src, dst = "azure:canadacentral", "gcp:asia-northeast1"
    a = planner.plan_cost_min(src, dst, 20.0, 50.0, backend="jax")
    b = planner.plan_cost_min(src, dst, 20.0, 50.0)
    assert a.cost_per_gb == pytest.approx(b.cost_per_gb, abs=1e-6)
    assert a.validate() == []


def test_infeasible_goal_batched_matches_sequential():
    top = toy_topology(n=5, seed=1)
    batched = solve_milp_batched(top, 0, 1, np.array([1e6]))[0]
    sequential = solve_milp(top, 0, 1, 1e6, mode="relaxed")
    assert not batched.ok and not sequential.ok


# ------------------------------------------------------- §5.1.3 on subgraphs
@pytest.mark.parametrize(
    "src,dst",
    [
        ("aws:us-east-1", "aws:ap-southeast-2"),
        ("azure:canadacentral", "gcp:asia-northeast1"),
        ("gcp:us-central1", "azure:koreacentral"),
    ],
)
def test_relaxed_within_one_percent_of_exact_on_pruned_subgraphs(src, dst):
    """Paper §5.1.3: round-down lands within 1% of the exact MILP, measured
    on the planner's own pruned candidate subgraphs."""
    planner = Planner(default_topology(), max_relays=4)
    sub, s, t, _ = planner._prune(src, dst)
    hi = planner.max_throughput(src, dst)
    goal = hi * 0.4
    rel = solve_milp(sub, s, t, goal, mode="relaxed")
    ex = solve_milp(sub, s, t, goal, mode="exact")
    assert rel.ok and ex.ok
    assert rel.objective <= ex.objective * 1.01 + 1e-9
    assert ex.objective >= ex.lp_objective - 1e-9
