"""Topology copy-on-write (grid freeze) and profile-grid determinism.

The planner caches derived data (edge lists, LP structures) keyed off
Topology *identity*: an in-place write to a grid after a structure was
cached would silently desynchronize every cached constraint matrix. The
grids are therefore frozen and ``with_tput`` is the sanctioned swap path.
The embedded profile grids are deterministic fixtures: the same seed must
produce bitwise-identical grids in every process.
"""

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    Planner,
    default_topology,
    grid_fingerprint,
    milp,
    toy_topology,
)

SRC = Path(__file__).resolve().parent.parent / "src"


# ------------------------------------------------------------- mutability
def test_inplace_grid_mutation_raises():
    top = toy_topology(n=5, seed=0)
    for arr in (top.tput, top.price_egress, top.price_vm,
                top.limit_ingress, top.limit_egress):
        with pytest.raises(ValueError):
            arr[0] = 99.0


def test_mutation_cannot_poison_cached_lp_structures():
    """Regression (ISSUE 4 satellite): before the freeze, ``top.tput[i,j]
    = x`` after a solve silently left every cached LPStructure built from
    the OLD grid. Now the write raises and the cache stays consistent."""
    top = toy_topology(n=5, seed=1)
    struct = milp.structure(top, 0, 1)
    coef_before = struct.A_ub[0].copy()
    with pytest.raises(ValueError):
        top.tput[0, 1] *= 0.01  # skylint: disable=SKY003
    # the cached structure is untouched and still keyed on this instance
    assert milp.structure(top, 0, 1) is struct
    assert np.array_equal(struct.A_ub[0], coef_before)


def test_with_tput_returns_fresh_instance_and_caches():
    top = toy_topology(n=5, seed=2)
    s0 = milp.structure(top, 0, 1)
    half = top.with_tput(scale=0.5)
    assert half is not top
    assert np.allclose(half.tput, top.tput * 0.5)
    assert half._lp_struct_cache == {}  # caches start clean
    s1 = milp.structure(half, 0, 1)
    assert s1 is not s0
    # the new structure's 4b rows reflect the new grid
    e = s1.n_edges
    k = 0
    u, w = s1.edges[k]
    assert s1.A_ub[k, e + half.num_regions + k] == pytest.approx(
        -half.tput[u, w] / half.limit_conn
    )
    # prices and caps are shared values (unchanged by the tput swap)
    assert np.array_equal(half.price_egress, top.price_egress)


def test_with_tput_argument_validation():
    top = toy_topology(n=4, seed=3)
    with pytest.raises(ValueError):
        top.with_tput()
    with pytest.raises(ValueError):
        top.with_tput(top.tput, scale=0.5)


def test_planner_on_with_tput_topology_sees_new_grid():
    top = toy_topology(n=6, seed=4)
    pl0 = Planner(top, max_relays=3)
    cap0 = pl0.max_throughput("toy:r0", "toy:r1")
    pl1 = Planner(top.with_tput(scale=0.5), max_relays=3)
    cap1 = pl1.max_throughput("toy:r0", "toy:r1")
    assert 0 < cap1 < cap0


# ----------------------------------------------------------- determinism
_FINGERPRINT_SNIPPET = """
import sys
sys.path.insert(0, {src!r})
from repro.core import default_topology, grid_fingerprint
from repro.core.profiles import toy_topology
print(grid_fingerprint(default_topology()))
print(grid_fingerprint(toy_topology(n=7, seed=123)))
"""


def _subprocess_fingerprints() -> list[str]:
    out = subprocess.run(
        [sys.executable, "-c", _FINGERPRINT_SNIPPET.format(src=str(SRC))],
        capture_output=True, text=True, timeout=300, check=True,
    )
    return out.stdout.split()


def test_profile_grids_bitwise_identical_across_processes():
    """Satellite: same seed => bitwise-identical grids in every process
    (the embedded measurement is a fixture, not a sample)."""
    here = [
        grid_fingerprint(default_topology()),
        grid_fingerprint(toy_topology(n=7, seed=123)),
    ]
    assert _subprocess_fingerprints() == here


def test_toy_topology_seed_sensitivity():
    a = grid_fingerprint(toy_topology(n=7, seed=1))
    b = grid_fingerprint(toy_topology(n=7, seed=2))
    assert a != b
    assert grid_fingerprint(toy_topology(n=7, seed=1)) == a


def test_drift_model_reproducible_across_processes():
    """Satellite: the drift model's grid at an arbitrary query time is
    bitwise-identical across processes (pure function of seed and t)."""
    from repro.calibrate import DriftModel

    top = default_topology()
    local = DriftModel(top, seed=42, n_incidents=2).tput_at(321.5)
    snippet = """
import sys
sys.path.insert(0, {src!r})
import hashlib, numpy as np
from repro.core import default_topology
from repro.calibrate import DriftModel
g = DriftModel(default_topology(), seed=42, n_incidents=2).tput_at(321.5)
print(hashlib.sha256(np.ascontiguousarray(g).tobytes()).hexdigest())
""".format(src=str(SRC))
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        capture_output=True, text=True, timeout=300, check=True,
    )
    import hashlib
    assert out.stdout.strip() == hashlib.sha256(
        np.ascontiguousarray(local).tobytes()
    ).hexdigest()
