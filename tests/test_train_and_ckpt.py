"""Training loop, fault tolerance, checkpointing, pipeline resume."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.ckpt.checkpoint import (
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)
from repro.data.pipeline import ShardedTokenPipeline
from repro.models import init_params
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture()
def cfg():
    return reduced(get_arch("smollm-135m"))


def test_loss_decreases(tmp_path, cfg):
    trainer = Trainer(
        cfg,
        TrainerConfig(steps=25, global_batch=4, seq_len=64, ckpt_every=100,
                      ckpt_dir=str(tmp_path), log_every=1),
        opt_cfg=OptConfig(lr=5e-3, warmup_steps=2, total_steps=25),
    )
    res = trainer.run()
    losses = res["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.005


def test_restart_after_injected_failure(tmp_path, cfg):
    fail_at = {7}
    trainer = Trainer(
        cfg,
        TrainerConfig(steps=12, global_batch=2, seq_len=32, ckpt_every=5,
                      ckpt_dir=str(tmp_path), log_every=2),
        opt_cfg=OptConfig(lr=1e-3, warmup_steps=2, total_steps=12),
        failure_injector=lambda s: s in fail_at and not fail_at.discard(s),
    )
    res = trainer.run()
    assert res["restarts"] == 1
    assert res["final_step"] == 12  # recovered and completed


def test_checkpoint_roundtrip_and_checksum(tmp_path, cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tree = {"params": params, "opt": opt}
    path = save_checkpoint(tmp_path, 7, tree, extra={"k": 1})
    restored, step, extra = load_checkpoint(path, tree)
    assert step == 7 and extra == {"k": 1}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # corrupt a leaf -> checksum failure
    victim = next(path.glob("leaf_*.npy"))
    data = bytearray(victim.read_bytes())
    data[-1] ^= 0xFF
    victim.write_bytes(bytes(data))
    with pytest.raises(IOError):
        load_checkpoint(path, tree)


def test_checkpoint_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.arange(8.0)}
    for s in (1, 2, 3):
        mgr.save_async(s, tree)
        mgr.wait()
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["step_00000002", "step_00000003"]
    restored, step, _ = mgr.restore(tree)
    assert step == 3


def test_pipeline_deterministic_resume(cfg):
    p1 = ShardedTokenPipeline(cfg, global_batch=2, seq_len=16, seed=9)
    batches = [next(p1) for _ in range(5)]
    state = None
    # consume 3, snapshot, then the next two must replay identically
    p2 = ShardedTokenPipeline(cfg, global_batch=2, seq_len=16, seed=9)
    for _ in range(3):
        next(p2)
    state = p2.state_dict()
    p3 = ShardedTokenPipeline(cfg, global_batch=2, seq_len=16, seed=9)
    p3.load_state_dict(state)
    for i in (3, 4):
        b = next(p3)
        np.testing.assert_array_equal(b["tokens"], batches[i]["tokens"])


def test_pipeline_prefetch_matches_sync(cfg):
    a = ShardedTokenPipeline(cfg, global_batch=2, seq_len=16, seed=4)
    b = ShardedTokenPipeline(cfg, global_batch=2, seq_len=16, seed=4).start()
    try:
        for _ in range(4):
            np.testing.assert_array_equal(next(a)["tokens"], next(b)["tokens"])
    finally:
        b.stop()


def test_optimizer_minimizes_quadratic():
    from repro.train.optimizer import adamw_update

    # long total_steps => effectively constant LR; large clip_norm so the
    # quadratic's big initial gradient isn't rescaled
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                    total_steps=10_000, clip_norm=1e9)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    for _ in range(120):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, params, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2
