"""Gateway (real bytes), checkpoint replication, compression, placement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Planner, default_topology, toy_topology
from repro.transfer.compression import (
    compress,
    compress_with_error_feedback,
    init_error_feedback,
)
from repro.transfer.gateway import BlobStore, transfer_objects


@pytest.fixture(scope="module")
def toy_plan():
    top = toy_topology(n=5, seed=2)
    return Planner(top, max_relays=3).plan_cost_min("toy:r0", "toy:r1", 2.0, 0.01)


def test_gateway_moves_bytes_exactly(toy_plan):
    rng = np.random.default_rng(0)
    src, dst = BlobStore(), BlobStore()
    keys = []
    for i in range(4):
        k = f"shard/{i:03d}.npy"
        src.put(k, rng.bytes(1_500_000 + i * 31337))
        keys.append(k)
    rep = transfer_objects(toy_plan, src, dst, keys, chunk_bytes=1 << 18)
    assert rep.checksum_failures == 0
    assert sorted(dst.keys()) == sorted(keys)
    for k in keys:
        assert dst.get(k) == src.get(k)
    # relays move bytes once per hop
    hops = max(len(p) - 1 for p, _ in toy_plan.paths())
    total = sum(src.size(k) for k in keys)
    assert rep.bytes_moved >= total  # at least one traversal


def test_checkpoint_replication_end_to_end(tmp_path):
    from repro.ckpt import replicate_checkpoint, save_checkpoint
    from repro.models import init_params
    from repro.configs import get_arch, reduced

    cfg = reduced(get_arch("smollm-135m"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    path = save_checkpoint(tmp_path, 3, {"params": params})
    top = default_topology()
    stores = {"gcp:europe-west4": BlobStore()}
    reports = replicate_checkpoint(
        path, top, "aws:us-east-1", list(stores), stores, tput_floor_gbps=5.0
    )
    (rep,) = reports
    assert rep.gateway.checksum_failures == 0
    assert rep.plan_tput_gbps >= 5.0 * 0.95
    assert stores["gcp:europe-west4"].exists("MANIFEST.json")


def test_error_feedback_preserves_signal():
    """With EF, the *cumulative* transmitted gradient converges to the
    cumulative true gradient (compression error doesn't accumulate)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=257) * 0.1,
                          jnp.float32)}
    ef = init_error_feedback(g)
    sent_total = jnp.zeros_like(g["w"])
    n = 30
    for _ in range(n):
        sent, ef = compress_with_error_feedback(g, ef)
        sent_total = sent_total + sent["w"]
    rel = float(jnp.linalg.norm(sent_total - n * g["w"]) /
                jnp.linalg.norm(n * g["w"]))
    assert rel < 0.01


def test_compress_is_bounded_lossy():
    x = jnp.asarray(np.random.default_rng(1).normal(size=1024), jnp.float32)
    y = compress(x)
    assert float(jnp.abs(y - x).max()) <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_shard_placement_prefers_cheap_sources():
    from repro.data.placement import plan_shard_sources

    top = default_topology()
    sources = plan_shard_sources(
        top,
        {0: ["aws:us-east-1", "gcp:asia-southeast1"],
         1: ["gcp:us-central1"]},
        consumer_region="aws:us-east-2",
        tput_floor_gbps=1.0,
    )
    assert sources[0].source_region == "aws:us-east-1"  # intra-cloud is cheap
    assert sources[0].plan_cost_per_gb < 0.05
    assert sources[1].source_region == "gcp:us-central1"
